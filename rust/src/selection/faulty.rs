//! Deterministic fault injection for cost sources.
//!
//! [`FaultySource`] wraps any inner [`CostSource`] and perturbs it with
//! three independently-toggled fault families, so the health subsystem
//! (drift detection, auto-recalibration, quarantine — see
//! [`health`](crate::health)) is testable end to end without real
//! hardware misbehaving on cue:
//!
//! * **multiplicative drift** — scales every cost; primitive columns get
//!   *different* effective factors (`d` raised to a per-column power in
//!   `[1, 2)`, seeded), because a uniform scale would leave argmin
//!   selections untouched and make "drift" undetectable by outcome.
//!   DLT costs scale by the plain factor `d`.
//! * **error returns** — a seeded per-query coin makes the source panic
//!   with an `injected fault:` message. The [`CostSource`] trait has no
//!   error channel by design (hot-path rows are infallible lookups), so
//!   a panic *is* the error path real sources have — and both consumers
//!   that must survive it (the service worker, the recalibration guard)
//!   already run sources under `catch_unwind`.
//! * **latency spikes** — a seeded per-query coin inserts a sleep,
//!   modelling a co-tenant stealing the machine mid-profile.
//!
//! Every decision is a pure function of `(seed, query key)` — never of
//! call order — so concurrent and sequential runs inject the *same*
//! faults on the same queries, and a test that replays a workload
//! replays its faults.

use super::CostSource;
use crate::layers::ConvConfig;
use crate::primitives::Layout;
use crate::simulator::noise::{fnv1a_words, SplitMix64};
use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Salt separating the per-query coins (error vs spike vs drift spread).
const SALT_ERROR: u64 = 0x4641554C545F4552; // "FAULT_ER"
const SALT_SPIKE: u64 = 0x4641554C545F5350; // "FAULT_SP"
const SALT_DRIFT: u64 = 0x4641554C545F4452; // "FAULT_DR"

/// A seeded fault-injecting wrapper around any cost source. All knobs are
/// atomic and may be flipped while the source is being served from other
/// threads — tests drive the health state machine by turning drift and
/// error injection on and off between requests.
pub struct FaultySource {
    inner: Arc<dyn CostSource>,
    seed: u64,
    /// Multiplicative drift factor as f64 bits (1.0 = off).
    drift: AtomicU64,
    /// Probability in [0, 1] (f64 bits) that a query panics.
    error_rate: AtomicU64,
    /// Probability in [0, 1] (f64 bits) that a query sleeps.
    spike_rate: AtomicU64,
    /// Spike duration in microseconds.
    spike_us: AtomicU64,
    queries: AtomicU64,
    injected_errors: AtomicU64,
    injected_spikes: AtomicU64,
}

impl FaultySource {
    /// Wrap `inner`; all fault families start disabled, so the wrapper is
    /// initially transparent (bit-identical costs).
    pub fn new(inner: Arc<dyn CostSource>, seed: u64) -> Self {
        Self {
            inner,
            seed,
            drift: AtomicU64::new(1.0f64.to_bits()),
            error_rate: AtomicU64::new(0.0f64.to_bits()),
            spike_rate: AtomicU64::new(0.0f64.to_bits()),
            spike_us: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            injected_errors: AtomicU64::new(0),
            injected_spikes: AtomicU64::new(0),
        }
    }

    /// Set the multiplicative drift factor (`1.0` disables). Primitive
    /// column `j` is scaled by `d^(1 + u_j)` with `u_j ∈ [0, 1)` seeded
    /// per column; DLT costs scale by `d`.
    pub fn set_drift(&self, d: f64) {
        assert!(d.is_finite() && d > 0.0, "drift factor must be positive, got {d}");
        self.drift.store(d.to_bits(), Ordering::Relaxed);
    }

    /// Set the per-query panic probability (`0.0` disables, `1.0` makes
    /// every query fail).
    pub fn set_error_rate(&self, p: f64) {
        assert!((0.0..=1.0).contains(&p), "error rate must be in [0,1], got {p}");
        self.error_rate.store(p.to_bits(), Ordering::Relaxed);
    }

    /// Set the per-query latency-spike probability and duration.
    pub fn set_latency_spikes(&self, p: f64, dur: Duration) {
        assert!((0.0..=1.0).contains(&p), "spike rate must be in [0,1], got {p}");
        self.spike_us.store(dur.as_micros() as u64, Ordering::Relaxed);
        self.spike_rate.store(p.to_bits(), Ordering::Relaxed);
    }

    /// Total queries that reached the wrapper (layer rows + DLT lookups)
    /// — the hammer test's "sampling fraction 0 adds zero shadow
    /// traffic" assertion reads this.
    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Queries that panicked by injection so far.
    pub fn injected_errors(&self) -> u64 {
        self.injected_errors.load(Ordering::Relaxed)
    }

    /// Queries that slept by injection so far.
    pub fn injected_spikes(&self) -> u64 {
        self.injected_spikes.load(Ordering::Relaxed)
    }

    fn drift_factor(&self) -> f64 {
        f64::from_bits(self.drift.load(Ordering::Relaxed))
    }

    /// Uniform [0, 1) coin for `(seed, salt, key)` — order-independent.
    fn coin(&self, salt: u64, key: &[u64]) -> f64 {
        let mut h = vec![self.seed, salt];
        h.extend_from_slice(key);
        SplitMix64::new(fnv1a_words(&h)).next_f64()
    }

    /// Shared per-query fault gate: count, maybe sleep, maybe panic.
    fn gate(&self, kind: &str, key: &[u64]) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let spike_rate = f64::from_bits(self.spike_rate.load(Ordering::Relaxed));
        if spike_rate > 0.0 && self.coin(SALT_SPIKE, key) < spike_rate {
            self.injected_spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(self.spike_us.load(Ordering::Relaxed)));
        }
        let error_rate = f64::from_bits(self.error_rate.load(Ordering::Relaxed));
        if error_rate > 0.0 && self.coin(SALT_ERROR, key) < error_rate {
            self.injected_errors.fetch_add(1, Ordering::Relaxed);
            panic!("injected fault: {kind} query failed (seed {})", self.seed);
        }
    }

    /// The per-column drift exponent spread `1 + u_j`, `u_j ∈ [0, 1)`.
    fn column_exponent(&self, j: usize) -> f64 {
        1.0 + self.coin(SALT_DRIFT, &[j as u64])
    }
}

impl CostSource for FaultySource {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        let key =
            [cfg.k as u64, cfg.c as u64, cfg.im as u64, cfg.s as u64, cfg.f as u64];
        self.gate("layer_costs", &key);
        let row = self.inner.layer_costs(cfg);
        let d = self.drift_factor();
        if d == 1.0 {
            return row;
        }
        Cow::Owned(
            row.iter()
                .enumerate()
                .map(|(j, t)| t.map(|v| v * d.powf(self.column_exponent(j))))
                .collect(),
        )
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        let key = [c as u64, im as u64, src.index() as u64, dst.index() as u64];
        self.gate("dlt_cost", &key);
        self.inner.dlt_cost(c, im, src, dst) * self.drift_factor()
    }

    // is_memoized stays false: every query must pass the fault gate, and
    // consumers wrap the source in their own CostCache where needed.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{machine, Simulator};

    fn wrapped(seed: u64) -> FaultySource {
        FaultySource::new(Arc::new(Simulator::new(machine::intel_i9_9900k())), seed)
    }

    fn cfg() -> ConvConfig {
        ConvConfig::new(64, 3, 224, 1, 3)
    }

    #[test]
    fn transparent_when_disabled() {
        let f = wrapped(1);
        let sim = Simulator::new(machine::intel_i9_9900k());
        assert_eq!(f.layer_costs(&cfg()).as_ref(), sim.layer_costs(&cfg()).as_ref());
        assert_eq!(
            f.dlt_cost(64, 224, Layout::Chw, Layout::Hwc),
            sim.dlt_cost(64, 224, Layout::Chw, Layout::Hwc)
        );
        assert_eq!(f.queries(), 2);
        assert_eq!(f.injected_errors(), 0);
    }

    #[test]
    fn drift_scales_columns_differently() {
        let f = wrapped(2);
        let clean: Vec<Option<f64>> = f.layer_costs(&cfg()).into_owned();
        f.set_drift(3.0);
        let drifted = f.layer_costs(&cfg());
        let ratios: Vec<f64> = clean
            .iter()
            .zip(drifted.iter())
            .filter_map(|(c, d)| Some(d.as_ref()? / c.as_ref()?))
            .collect();
        assert!(ratios.len() > 2);
        // every column at least 3x (exponent ≥ 1), below 9x (exponent < 2)
        for r in &ratios {
            assert!(*r >= 3.0 - 1e-9 && *r < 9.0 + 1e-9, "{r}");
        }
        // and the spread is real: not all columns share one factor
        let spread = ratios.iter().cloned().fold(0.0f64, f64::max)
            / ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1.05, "{spread}");
        // deterministic: same query, same drifted row
        assert_eq!(drifted.as_ref(), f.layer_costs(&cfg()).as_ref());
    }

    #[test]
    fn error_injection_is_deterministic_per_query() {
        let f = wrapped(3);
        f.set_error_rate(0.5);
        let mut failed = Vec::new();
        for im in [7u32, 14, 28, 56, 112, 224] {
            let c = ConvConfig::new(32, 16, im, 1, 3);
            let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f.layer_costs(&c).len()
            }))
            .is_err();
            failed.push(died);
        }
        assert!(failed.iter().any(|&d| d), "rate 0.5 over 6 keys hit none");
        assert!(!failed.iter().all(|&d| d), "rate 0.5 over 6 keys hit all");
        // replay: the same keys fail, independent of order
        for (im, &expect) in [224u32, 112, 56, 28, 14, 7].iter().zip(failed.iter().rev()) {
            let c = ConvConfig::new(32, 16, *im, 1, 3);
            let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f.layer_costs(&c).len()
            }))
            .is_err();
            assert_eq!(died, expect, "im={im}");
        }
        assert!(f.injected_errors() > 0);
        // disabling stops the panics on the very same keys
        f.set_error_rate(0.0);
        for im in [7u32, 14, 28, 56, 112, 224] {
            let _ = f.layer_costs(&ConvConfig::new(32, 16, im, 1, 3));
        }
    }

    #[test]
    fn rate_one_fails_everything_and_message_is_tagged() {
        let f = wrapped(4);
        f.set_error_rate(1.0);
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.dlt_cost(8, 7, Layout::Chw, Layout::Hwc)
        }))
        .unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.starts_with("injected fault:"), "{msg}");
    }

    #[test]
    fn latency_spikes_sleep_but_do_not_corrupt() {
        let f = wrapped(5);
        let clean = f.dlt_cost(16, 14, Layout::Chw, Layout::Hcw);
        f.set_latency_spikes(1.0, Duration::from_micros(200));
        let t0 = std::time::Instant::now();
        let spiked = f.dlt_cost(16, 14, Layout::Chw, Layout::Hcw);
        assert!(t0.elapsed() >= Duration::from_micros(200));
        assert_eq!(clean, spiked);
        assert!(f.injected_spikes() >= 1);
    }
}

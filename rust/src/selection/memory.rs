//! Memory-aware selection — the paper's §2.1 pointer to TASO [28, 29]:
//! primitives differ hugely in workspace footprint (im2col materialises a
//! c·f·f·o² patch matrix; kn2/mec exist *because* of it), so
//! memory-constrained devices trade time for space. We expose the
//! workspace model and a penalised PBQP objective
//! `time + λ · max(0, workspace − budget)` per layer, reproducing TASO's
//! trade-off curve shape (time rises as the budget tightens).
//!
//! The budgeted instance is factored as a crate-internal
//! `BudgetedProblem`: the graph topology, edge matrices, and unpenalised
//! node times are built once and only the node costs are re-priced per
//! budget level, via [`pbqp::ReusableSolver`]. A single point query
//! ([`select_with_budget`]) and the full Pareto sweep
//! ([`super::pareto::ParetoFront::compute`]) share this path, so a front
//! point and a fresh per-budget solve are bit-identical by construction.

use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::pbqp;
use crate::primitives::{catalog, Family, Primitive};
use crate::selection::{with_cache, CostSource, Selection};
use anyhow::{ensure, Result};

/// Workspace bytes a primitive needs beyond input/weights/output.
pub fn workspace_bytes(prim: &Primitive, cfg: &ConvConfig) -> f64 {
    const B: f64 = 4.0;
    let Some(o) = cfg.out_size() else { return 0.0 };
    let (k, c, im, o, f) =
        (cfg.k as f64, cfg.c as f64, cfg.im as f64, o as f64, cfg.f as f64);
    match prim.family {
        // the full patch matrix (only the copy variants materialise it)
        Family::Im2 => {
            if prim.copy {
                c * f * f * o * o * B
            } else {
                c * o * o * B // one offset slice in flight
            }
        }
        // full-image product before the shifted accumulation
        Family::Kn2 => k * im * im * B,
        // U and V transform tensors
        Family::Wino3 | Family::Wino5 => {
            let m = prim.tile_m as f64;
            let a = m + f - 1.0;
            let tiles = (o / m).ceil().powi(2);
            (a * a * k * c + a * a * tiles * c) * B
        }
        // MEC's defining property: the width-lowered L matrix, f× smaller
        Family::Mec => o * im * c * f * B,
        Family::Direct | Family::Conv1x1 => 0.0,
    }
}

/// Peak workspace of an assignment across the network.
pub fn peak_workspace(net: &Network, sel: &Selection) -> f64 {
    net.layers
        .iter()
        .zip(&sel.primitive)
        .map(|(cfg, &p)| workspace_bytes(&catalog()[p], cfg))
        .fold(0.0, f64::max)
}

/// A budgeted selection instance with the budget-independent parts
/// (topology, edge matrices, unpenalised times, workspace table, and the
/// solver's merged-edge arena) built once, so many budget levels re-price
/// and re-solve without rebuilding anything.
pub(crate) struct BudgetedProblem {
    /// choices[u] — catalog indices applicable at layer u, in row order.
    choices: Vec<Vec<usize>>,
    /// workspace[u][i] — workspace bytes of choices[u][i] at layer u.
    workspace: Vec<Vec<f64>>,
    /// Graph whose node costs are the *unpenalised* times; edges carry
    /// the data-layout transformation matrices. `cost_of` on it yields
    /// the true estimated time of an assignment.
    graph: pbqp::Graph,
    solver: pbqp::ReusableSolver,
}

impl BudgetedProblem {
    /// Build the budget-independent instance. `costs` should already be
    /// memoized (callers go through [`with_cache`]).
    pub(crate) fn build(net: &Network, costs: &dyn CostSource) -> Result<Self> {
        let cat = catalog();
        let mut node_costs = Vec::with_capacity(net.n_layers());
        let mut choices = Vec::with_capacity(net.n_layers());
        let mut workspace = Vec::with_capacity(net.n_layers());
        for cfg in &net.layers {
            let row = costs.layer_costs(cfg);
            let mut ch = Vec::new();
            let mut nc = Vec::new();
            let mut ws = Vec::new();
            for (p, t) in row.iter().enumerate() {
                if let Some(t) = t {
                    ch.push(p);
                    nc.push(*t);
                    ws.push(workspace_bytes(&cat[p], cfg));
                }
            }
            ensure!(!ch.is_empty(), "no applicable primitive for {cfg:?}");
            node_costs.push(nc);
            choices.push(ch);
            workspace.push(ws);
        }
        let mut graph = pbqp::Graph::new(node_costs);
        for &(u, v) in &net.edges {
            let c = net.layers[u].k;
            let im = net.layers[v].im;
            let m = costs.dlt_matrix3(c, im);
            let cu = &choices[u];
            let cv = &choices[v];
            let mut mat = Vec::with_capacity(cu.len() * cv.len());
            for &pu in cu {
                for &pv in cv {
                    mat.push(m[cat[pu].out_layout.index()][cat[pv].in_layout.index()]);
                }
            }
            graph.add_edge(u, v, mat);
        }
        let solver = pbqp::ReusableSolver::new(&graph);
        Ok(Self { choices, workspace, graph, solver })
    }

    /// Workspace values over all (layer, applicable primitive) pairs —
    /// the distinct budget levels worth sweeping.
    pub(crate) fn workspace_levels(&self) -> impl Iterator<Item = f64> + '_ {
        self.workspace.iter().flatten().copied()
    }

    /// Node costs penalised for `budget_bytes` at `lambda_ms_per_mb`
    /// (TASO-style soft constraint: overshoot charged per MiB).
    fn priced(&self, budget_bytes: f64, lambda_ms_per_mb: f64) -> Vec<Vec<f64>> {
        self.graph
            .node_costs
            .iter()
            .zip(&self.workspace)
            .map(|(times, ws)| {
                times
                    .iter()
                    .zip(ws)
                    .map(|(t, w)| {
                        let over = (*w - budget_bytes).max(0.0);
                        *t + over / (1024.0 * 1024.0) * lambda_ms_per_mb
                    })
                    .collect()
            })
            .collect()
    }

    /// Solve at one budget level. `objective_ms` is the penalised PBQP
    /// objective; `estimated_ms` is the true (unpenalised) time of the
    /// chosen assignment over the same cost tables.
    pub(crate) fn solve_at(
        &self,
        budget_bytes: f64,
        lambda_ms_per_mb: f64,
    ) -> Selection {
        let sol = self.solver.solve_with(&self.priced(budget_bytes, lambda_ms_per_mb));
        Selection {
            primitive: sol
                .choice
                .iter()
                .enumerate()
                .map(|(u, &ci)| self.choices[u][ci])
                .collect(),
            objective_ms: sol.cost,
            estimated_ms: self.graph.cost_of(&sol.choice),
        }
    }
}

/// Select with a per-layer workspace budget: overshoot is charged at
/// `lambda_ms_per_mb` in the PBQP objective (soft constraint, TASO-style).
pub fn select_with_budget(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    with_cache(costs, |c: &dyn CostSource| {
        select_with_budget_inner(net, c, budget_bytes, lambda_ms_per_mb)
    })
}

fn select_with_budget_inner(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    Ok(BudgetedProblem::build(net, costs)?.solve_at(budget_bytes, lambda_ms_per_mb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::selection;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn im2col_copy_is_the_memory_hog() {
        let cfg = ConvConfig::new(256, 256, 56, 1, 3);
        let copy = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let scan = catalog().iter().find(|p| p.name == "im2col-scan-ab-ki").unwrap();
        let mec = catalog().iter().find(|p| p.name == "mec-col").unwrap();
        let wc = workspace_bytes(copy, &cfg);
        assert!(wc > workspace_bytes(scan, &cfg) * 5.0);
        assert!(wc > workspace_bytes(mec, &cfg) * 2.0, "MEC must be leaner");
    }

    #[test]
    fn tightening_budget_trades_time_for_space() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let net = networks::vgg(11);
        let free = selection::select(&net, &sim).unwrap();
        let free_peak = peak_workspace(&net, &free);
        // budget at 10% of the unconstrained peak, steep penalty
        let tight = select_with_budget(&net, &sim, free_peak * 0.1, 50.0).unwrap();
        let tight_peak = peak_workspace(&net, &tight);
        let tight_time = selection::evaluate(&net, &tight, &sim).unwrap();
        assert!(tight_peak < free_peak, "{tight_peak} !< {free_peak}");
        assert!(tight_time >= free.estimated_ms, "time cannot improve");
    }

    #[test]
    fn infinite_budget_recovers_unconstrained() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, f64::INFINITY, 50.0).unwrap();
        assert_eq!(free.primitive, same.primitive);
    }

    #[test]
    fn zero_lambda_ignores_budget() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, 0.0, 0.0).unwrap();
        assert!((same.estimated_ms - free.estimated_ms).abs() < 1e-9);
    }

    #[test]
    fn objective_carries_penalty_but_estimate_is_true_time() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let free_peak = peak_workspace(&net, &free);
        // tight budget: some overshoot is unavoidable, so the penalised
        // objective strictly exceeds the true time of the chosen assignment
        let tight = select_with_budget(&net, &sim, free_peak * 0.01, 50.0).unwrap();
        assert!(
            tight.objective_ms > tight.estimated_ms,
            "{} !> {}",
            tight.objective_ms,
            tight.estimated_ms
        );
        // and the estimate is exactly what evaluate() reports
        let ev = selection::evaluate(&net, &tight, &sim).unwrap();
        assert_eq!(tight.estimated_ms, ev);
        // slack budget: no penalty anywhere, the two coincide
        let slack = select_with_budget(&net, &sim, f64::INFINITY, 50.0).unwrap();
        assert_eq!(slack.objective_ms, slack.estimated_ms);
    }
}

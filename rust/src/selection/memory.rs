//! Memory-aware selection — the paper's §2.1 pointer to TASO [28, 29]:
//! primitives differ hugely in workspace footprint (im2col materialises a
//! c·f·f·o² patch matrix; kn2/mec exist *because* of it), so
//! memory-constrained devices trade time for space. We expose the
//! workspace model and a penalised PBQP objective
//! `time + λ · max(0, workspace − budget)` per layer, reproducing TASO's
//! trade-off curve shape (time rises as the budget tightens).
//!
//! The budgeted instance is factored as a crate-internal
//! `BudgetedProblem`: a compiled [`SelectionPlan`] (flat choice / time /
//! workspace arenas plus the solver's merged-edge elimination template)
//! paired with a retained [`PlanScratch`], so each budget level only
//! re-prices the penalty terms and re-runs the reductions. A single
//! point query ([`select_with_budget`]), the full Pareto sweep
//! ([`super::pareto::ParetoFront::compute`]) and the coordinator's warm
//! plan-cache solves all share this path, so a front point, a fresh
//! per-budget solve, and a warm plan solve are bit-identical by
//! construction.

use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::primitives::{catalog, Family, Primitive};
use crate::selection::plan::{PlanScratch, SelectionPlan};
use crate::selection::{with_cache, CostSource, Selection};
use anyhow::Result;

/// Workspace bytes a primitive needs beyond input/weights/output.
pub fn workspace_bytes(prim: &Primitive, cfg: &ConvConfig) -> f64 {
    const B: f64 = 4.0;
    let Some(o) = cfg.out_size() else { return 0.0 };
    let (k, c, im, o, f) =
        (cfg.k as f64, cfg.c as f64, cfg.im as f64, o as f64, cfg.f as f64);
    match prim.family {
        // the full patch matrix (only the copy variants materialise it)
        Family::Im2 => {
            if prim.copy {
                c * f * f * o * o * B
            } else {
                c * o * o * B // one offset slice in flight
            }
        }
        // full-image product before the shifted accumulation
        Family::Kn2 => k * im * im * B,
        // U and V transform tensors
        Family::Wino3 | Family::Wino5 => {
            let m = prim.tile_m as f64;
            let a = m + f - 1.0;
            let tiles = (o / m).ceil().powi(2);
            (a * a * k * c + a * a * tiles * c) * B
        }
        // MEC's defining property: the width-lowered L matrix, f× smaller
        Family::Mec => o * im * c * f * B,
        Family::Direct | Family::Conv1x1 => 0.0,
    }
}

/// Peak workspace of an assignment across the network.
pub fn peak_workspace(net: &Network, sel: &Selection) -> f64 {
    net.layers
        .iter()
        .zip(&sel.primitive)
        .map(|(cfg, &p)| workspace_bytes(&catalog()[p], cfg))
        .fold(0.0, f64::max)
}

/// A budgeted selection instance: a compiled [`SelectionPlan`] (the
/// budget-independent topology, edge matrices, unpenalised times and
/// workspace table in flat arenas) plus a retained [`PlanScratch`], so
/// many budget levels re-price and re-solve without rebuilding — or
/// allocating — anything.
pub(crate) struct BudgetedProblem {
    plan: SelectionPlan,
    scratch: PlanScratch,
}

impl BudgetedProblem {
    /// Build the budget-independent instance. `costs` should already be
    /// memoized (callers go through [`with_cache`]).
    pub(crate) fn build(net: &Network, costs: &dyn CostSource) -> Result<Self> {
        Ok(Self {
            plan: SelectionPlan::compile_inner(net, costs)?,
            scratch: PlanScratch::default(),
        })
    }

    /// Workspace values over all (layer, applicable primitive) pairs —
    /// the distinct budget levels worth sweeping.
    pub(crate) fn workspace_levels(&self) -> impl Iterator<Item = f64> + '_ {
        self.plan.workspace_levels()
    }

    /// Solve at one budget level. `objective_ms` is the penalised PBQP
    /// objective; `estimated_ms` is the true (unpenalised) time of the
    /// chosen assignment over the same cost tables.
    pub(crate) fn solve_at(
        &mut self,
        budget_bytes: f64,
        lambda_ms_per_mb: f64,
    ) -> Selection {
        self.plan.with_budget_into(budget_bytes, lambda_ms_per_mb, &mut self.scratch).to_selection()
    }
}

/// Select with a per-layer workspace budget: overshoot is charged at
/// `lambda_ms_per_mb` in the PBQP objective (soft constraint, TASO-style).
pub fn select_with_budget(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    with_cache(costs, |c: &dyn CostSource| {
        select_with_budget_inner(net, c, budget_bytes, lambda_ms_per_mb)
    })
}

fn select_with_budget_inner(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    let mut prob = BudgetedProblem::build(net, costs)?;
    Ok(prob.solve_at(budget_bytes, lambda_ms_per_mb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::selection;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn im2col_copy_is_the_memory_hog() {
        let cfg = ConvConfig::new(256, 256, 56, 1, 3);
        let copy = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let scan = catalog().iter().find(|p| p.name == "im2col-scan-ab-ki").unwrap();
        let mec = catalog().iter().find(|p| p.name == "mec-col").unwrap();
        let wc = workspace_bytes(copy, &cfg);
        assert!(wc > workspace_bytes(scan, &cfg) * 5.0);
        assert!(wc > workspace_bytes(mec, &cfg) * 2.0, "MEC must be leaner");
    }

    #[test]
    fn tightening_budget_trades_time_for_space() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let net = networks::vgg(11);
        let free = selection::select(&net, &sim).unwrap();
        let free_peak = peak_workspace(&net, &free);
        // budget at 10% of the unconstrained peak, steep penalty
        let tight = select_with_budget(&net, &sim, free_peak * 0.1, 50.0).unwrap();
        let tight_peak = peak_workspace(&net, &tight);
        let tight_time = selection::evaluate(&net, &tight, &sim).unwrap();
        assert!(tight_peak < free_peak, "{tight_peak} !< {free_peak}");
        assert!(tight_time >= free.estimated_ms, "time cannot improve");
    }

    #[test]
    fn infinite_budget_recovers_unconstrained() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, f64::INFINITY, 50.0).unwrap();
        assert_eq!(free.primitive, same.primitive);
    }

    #[test]
    fn zero_lambda_ignores_budget() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, 0.0, 0.0).unwrap();
        assert!((same.estimated_ms - free.estimated_ms).abs() < 1e-9);
    }

    #[test]
    fn objective_carries_penalty_but_estimate_is_true_time() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let free_peak = peak_workspace(&net, &free);
        // tight budget: some overshoot is unavoidable, so the penalised
        // objective strictly exceeds the true time of the chosen assignment
        let tight = select_with_budget(&net, &sim, free_peak * 0.01, 50.0).unwrap();
        assert!(
            tight.objective_ms > tight.estimated_ms,
            "{} !> {}",
            tight.objective_ms,
            tight.estimated_ms
        );
        // and the estimate is exactly what evaluate() reports
        let ev = selection::evaluate(&net, &tight, &sim).unwrap();
        assert_eq!(tight.estimated_ms, ev);
        // slack budget: no penalty anywhere, the two coincide
        let slack = select_with_budget(&net, &sim, f64::INFINITY, 50.0).unwrap();
        assert_eq!(slack.objective_ms, slack.estimated_ms);
    }
}

//! Memory-aware selection — the paper's §2.1 pointer to TASO [28, 29]:
//! primitives differ hugely in workspace footprint (im2col materialises a
//! c·f·f·o² patch matrix; kn2/mec exist *because* of it), so
//! memory-constrained devices trade time for space. We expose the
//! workspace model and a penalised PBQP objective
//! `time + λ · max(0, workspace − budget)` per layer, reproducing TASO's
//! trade-off curve shape (time rises as the budget tightens).

use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::pbqp;
use crate::primitives::{catalog, Family, Primitive};
use crate::selection::{with_cache, CostSource, Selection};
use anyhow::{ensure, Result};

/// Workspace bytes a primitive needs beyond input/weights/output.
pub fn workspace_bytes(prim: &Primitive, cfg: &ConvConfig) -> f64 {
    const B: f64 = 4.0;
    let Some(o) = cfg.out_size() else { return 0.0 };
    let (k, c, im, o, f) =
        (cfg.k as f64, cfg.c as f64, cfg.im as f64, o as f64, cfg.f as f64);
    match prim.family {
        // the full patch matrix (only the copy variants materialise it)
        Family::Im2 => {
            if prim.copy {
                c * f * f * o * o * B
            } else {
                c * o * o * B // one offset slice in flight
            }
        }
        // full-image product before the shifted accumulation
        Family::Kn2 => k * im * im * B,
        // U and V transform tensors
        Family::Wino3 | Family::Wino5 => {
            let m = prim.tile_m as f64;
            let a = m + f - 1.0;
            let tiles = (o / m).ceil().powi(2);
            (a * a * k * c + a * a * tiles * c) * B
        }
        // MEC's defining property: the width-lowered L matrix, f× smaller
        Family::Mec => o * im * c * f * B,
        Family::Direct | Family::Conv1x1 => 0.0,
    }
}

/// Peak workspace of an assignment across the network.
pub fn peak_workspace(net: &Network, sel: &Selection) -> f64 {
    net.layers
        .iter()
        .zip(&sel.primitive)
        .map(|(cfg, &p)| workspace_bytes(&catalog()[p], cfg))
        .fold(0.0, f64::max)
}

/// Select with a per-layer workspace budget: overshoot is charged at
/// `lambda_ms_per_mb` in the PBQP objective (soft constraint, TASO-style).
pub fn select_with_budget(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    with_cache(costs, |c: &dyn CostSource| {
        select_with_budget_inner(net, c, budget_bytes, lambda_ms_per_mb)
    })
}

fn select_with_budget_inner(
    net: &Network,
    costs: &dyn CostSource,
    budget_bytes: f64,
    lambda_ms_per_mb: f64,
) -> Result<Selection> {
    let cat = catalog();
    let mut node_costs = Vec::with_capacity(net.n_layers());
    let mut choices = Vec::with_capacity(net.n_layers());
    for cfg in &net.layers {
        let row = costs.layer_costs(cfg);
        let mut ch = Vec::new();
        let mut nc = Vec::new();
        for (p, t) in row.iter().enumerate() {
            if let Some(t) = t {
                let over = (workspace_bytes(&cat[p], cfg) - budget_bytes).max(0.0);
                ch.push(p);
                nc.push(*t + over / (1024.0 * 1024.0) * lambda_ms_per_mb);
            }
        }
        ensure!(!ch.is_empty(), "no applicable primitive for {cfg:?}");
        node_costs.push(nc);
        choices.push(ch);
    }
    let mut graph = pbqp::Graph::new(node_costs);
    for &(u, v) in &net.edges {
        let c = net.layers[u].k;
        let im = net.layers[v].im;
        let m = costs.dlt_matrix3(c, im);
        let cu = &choices[u];
        let cv = &choices[v];
        let mut mat = Vec::with_capacity(cu.len() * cv.len());
        for &pu in cu {
            for &pv in cv {
                mat.push(m[cat[pu].out_layout.index()][cat[pv].in_layout.index()]);
            }
        }
        graph.add_edge(u, v, mat);
    }
    let sol = pbqp::solve(&graph);
    Ok(Selection {
        primitive: sol
            .choice
            .iter()
            .enumerate()
            .map(|(u, &ci)| choices[u][ci])
            .collect(),
        estimated_ms: sol.cost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::selection;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn im2col_copy_is_the_memory_hog() {
        let cfg = ConvConfig::new(256, 256, 56, 1, 3);
        let copy = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let scan = catalog().iter().find(|p| p.name == "im2col-scan-ab-ki").unwrap();
        let mec = catalog().iter().find(|p| p.name == "mec-col").unwrap();
        let wc = workspace_bytes(copy, &cfg);
        assert!(wc > workspace_bytes(scan, &cfg) * 5.0);
        assert!(wc > workspace_bytes(mec, &cfg) * 2.0, "MEC must be leaner");
    }

    #[test]
    fn tightening_budget_trades_time_for_space() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let net = networks::vgg(11);
        let free = selection::select(&net, &sim).unwrap();
        let free_peak = peak_workspace(&net, &free);
        // budget at 10% of the unconstrained peak, steep penalty
        let tight = select_with_budget(&net, &sim, free_peak * 0.1, 50.0).unwrap();
        let tight_peak = peak_workspace(&net, &tight);
        let tight_time = selection::evaluate(&net, &tight, &sim).unwrap();
        assert!(tight_peak < free_peak, "{tight_peak} !< {free_peak}");
        assert!(tight_time >= free.estimated_ms, "time cannot improve");
    }

    #[test]
    fn infinite_budget_recovers_unconstrained() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, f64::INFINITY, 50.0).unwrap();
        assert_eq!(free.primitive, same.primitive);
    }

    #[test]
    fn zero_lambda_ignores_budget() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let net = networks::alexnet();
        let free = selection::select(&net, &sim).unwrap();
        let same = select_with_budget(&net, &sim, 0.0, 0.0).unwrap();
        assert!((same.estimated_ms - free.estimated_ms).abs() < 1e-9);
    }
}

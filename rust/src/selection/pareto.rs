//! Time×space Pareto fronts — the full TASO-style trade-off curve
//! (PAPERS.md, arxiv 2005.10709) instead of one budgeted point query.
//!
//! [`ParetoFront::compute`] sweeps the memory-budget axis in **one pass**
//! per (network, cost source): the PBQP topology, edge matrices,
//! unpenalised times and the solver's merged-edge arena are built once
//! (see `BudgetedProblem` in [`crate::selection::memory`]), and each
//! budget level only
//! re-prices the node costs and re-runs the reductions via
//! [`pbqp::ReusableSolver`](crate::pbqp::ReusableSolver). The swept
//! levels are exactly the distinct `workspace_bytes` values over every
//! (layer, applicable primitive) pair plus zero — between two adjacent
//! levels the penalty terms vary continuously with no new `max(0, ·)`
//! kink, so no optimum is skipped that the per-layer soft constraint
//! could express.
//!
//! Because a sweep level and a fresh
//! [`select_with_budget`](crate::selection::memory::select_with_budget)
//! call share the same pricing arithmetic and solver path, every front
//! point is
//! **bit-identical** to an exact per-budget solve at its
//! `budget_bytes` — the invariant the differential suite in
//! `rust/tests/pareto.rs` pins down.

use crate::networks::Network;
use crate::selection::memory::{peak_workspace, BudgetedProblem};
use crate::selection::{with_cache, CostSource, Selection};
use anyhow::Result;

/// Penalty rate used by the coordinator's front cache: ms charged per
/// MiB of per-layer workspace overshoot. Steep enough that the solver
/// only overshoots a budget when no applicable primitive fits under it.
pub const DEFAULT_LAMBDA_MS_PER_MB: f64 = 50.0;

/// One non-dominated point of a [`ParetoFront`].
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// The swept budget level (bytes) this point was solved at.
    pub budget_bytes: f64,
    /// Peak per-layer workspace (bytes) of [`Self::selection`].
    pub peak_workspace_bytes: f64,
    /// True (unpenalised) network time of [`Self::selection`], ms.
    pub true_time_ms: f64,
    /// The assignment realising this trade-off.
    pub selection: Selection,
}

/// The non-dominated time×space trade-off curve for one network under
/// one cost source: `peak_workspace_bytes` strictly increasing,
/// `true_time_ms` strictly decreasing across [`Self::points`].
///
/// ```
/// use primsel::networks;
/// use primsel::selection::pareto::ParetoFront;
/// use primsel::simulator::{machine, Simulator};
///
/// let sim = Simulator::new(machine::intel_i9_9900k());
/// let net = networks::alexnet();
/// let front = ParetoFront::compute(&net, &sim, 50.0).unwrap();
/// assert!(!front.is_empty());
/// // an unbounded budget admits the fastest point on the front
/// let fastest = front.fastest_under(f64::INFINITY).unwrap();
/// assert_eq!(fastest.true_time_ms, front.optimal_time_ms());
/// // the trade-off shape: every earlier point is smaller but slower
/// for p in &front.points {
///     assert!(p.peak_workspace_bytes <= fastest.peak_workspace_bytes);
///     assert!(p.true_time_ms >= fastest.true_time_ms);
/// }
/// ```
#[derive(Debug, Clone)]
pub struct ParetoFront {
    /// Name of the network the front was computed for.
    pub network: String,
    /// Penalty rate the sweep solved with.
    pub lambda_ms_per_mb: f64,
    /// Non-dominated points, sorted by increasing peak workspace
    /// (equivalently: decreasing true time).
    pub points: Vec<ParetoPoint>,
    /// Every budget level the sweep solved (sorted, deduplicated) —
    /// kept so differential tests can re-solve each level exactly.
    pub swept_budgets: Vec<f64>,
}

impl ParetoFront {
    /// Sweep the budget axis for `net` under `costs` and keep the
    /// non-dominated points. One graph build, one solver arena; one
    /// re-priced solve per distinct workspace level.
    pub fn compute(
        net: &Network,
        costs: &dyn CostSource,
        lambda_ms_per_mb: f64,
    ) -> Result<Self> {
        with_cache(costs, |c: &dyn CostSource| {
            Self::compute_inner(net, c, lambda_ms_per_mb)
        })
    }

    fn compute_inner(
        net: &Network,
        costs: &dyn CostSource,
        lambda_ms_per_mb: f64,
    ) -> Result<Self> {
        let mut prob = BudgetedProblem::build(net, costs)?;
        let mut budgets: Vec<f64> = prob.workspace_levels().collect();
        budgets.push(0.0);
        budgets.sort_by(|a, b| a.total_cmp(b));
        budgets.dedup();
        let mut raw = Vec::with_capacity(budgets.len());
        for &budget in &budgets {
            let sel = prob.solve_at(budget, lambda_ms_per_mb);
            let peak = peak_workspace(net, &sel);
            raw.push(ParetoPoint {
                budget_bytes: budget,
                peak_workspace_bytes: peak,
                true_time_ms: sel.estimated_ms,
                selection: sel,
            });
        }
        Ok(Self {
            network: net.name.clone(),
            lambda_ms_per_mb,
            points: pareto_filter(raw),
            swept_budgets: budgets,
        })
    }

    /// The fastest point whose peak workspace fits under `budget_bytes`,
    /// or `None` if even the leanest point exceeds it.
    pub fn fastest_under(&self, budget_bytes: f64) -> Option<&ParetoPoint> {
        // points are sorted by increasing peak and decreasing time, so
        // the last fitting point is the fastest fitting point
        self.points.iter().rev().find(|p| p.peak_workspace_bytes <= budget_bytes)
    }

    /// The smallest-footprint point within `pct` percent of the
    /// unconstrained optimum time. `pct = 0.0` returns the fastest
    /// point; larger slack admits leaner points.
    pub fn smallest_within_pct(&self, pct: f64) -> Option<&ParetoPoint> {
        let threshold = self.optimal_time_ms() * (1.0 + pct / 100.0);
        self.points.iter().find(|p| p.true_time_ms <= threshold)
    }

    /// True time of the fastest (unconstrained-optimal) point, ms.
    pub fn optimal_time_ms(&self) -> f64 {
        self.points.last().expect("front is never empty").true_time_ms
    }

    /// Peak workspace of the leanest point, bytes — the floor below
    /// which no budget is satisfiable.
    pub fn min_peak_bytes(&self) -> f64 {
        self.points.first().expect("front is never empty").peak_workspace_bytes
    }

    /// Number of non-dominated points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the front has no points (never true for a computed
    /// front — kept for the `len`/`is_empty` convention).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Keep the non-dominated points: sort by (peak asc, time asc, budget
/// asc) and keep a point iff it is strictly faster than everything
/// kept so far. Yields strictly increasing peak, strictly decreasing
/// time; ties collapse to the lowest-budget representative.
fn pareto_filter(mut raw: Vec<ParetoPoint>) -> Vec<ParetoPoint> {
    raw.sort_by(|a, b| {
        a.peak_workspace_bytes
            .total_cmp(&b.peak_workspace_bytes)
            .then(a.true_time_ms.total_cmp(&b.true_time_ms))
            .then(a.budget_bytes.total_cmp(&b.budget_bytes))
    });
    let mut kept: Vec<ParetoPoint> = Vec::new();
    for p in raw {
        if kept.last().map_or(true, |last| p.true_time_ms < last.true_time_ms) {
            kept.push(p);
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::selection;
    use crate::simulator::{machine, Simulator};

    fn front(net: &Network) -> ParetoFront {
        let sim = Simulator::new(machine::intel_i9_9900k());
        ParetoFront::compute(net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap()
    }

    #[test]
    fn front_is_strictly_monotone() {
        let f = front(&networks::alexnet());
        assert!(!f.is_empty());
        for w in f.points.windows(2) {
            assert!(w[0].peak_workspace_bytes < w[1].peak_workspace_bytes);
            assert!(w[0].true_time_ms > w[1].true_time_ms);
        }
    }

    #[test]
    fn fastest_point_is_the_unconstrained_optimum() {
        let net = networks::alexnet();
        let sim = Simulator::new(machine::intel_i9_9900k());
        let f = ParetoFront::compute(&net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        let free = selection::select(&net, &sim).unwrap();
        let fastest = f.fastest_under(f64::INFINITY).unwrap();
        assert_eq!(fastest.selection.primitive, free.primitive);
        assert_eq!(f.optimal_time_ms(), free.estimated_ms);
    }

    #[test]
    fn fastest_under_unsatisfiable_budget_is_none() {
        let f = front(&networks::alexnet());
        assert!(f.fastest_under(-1.0).is_none());
        assert!(f.fastest_under(f.min_peak_bytes()).is_some());
    }

    #[test]
    fn zero_pct_slack_returns_the_fastest_point() {
        let f = front(&networks::alexnet());
        let p = f.smallest_within_pct(0.0).unwrap();
        assert_eq!(p.true_time_ms, f.optimal_time_ms());
        // generous slack admits a point no larger than the fastest
        let lean = f.smallest_within_pct(1e6).unwrap();
        assert!(lean.peak_workspace_bytes <= p.peak_workspace_bytes);
        assert_eq!(lean.peak_workspace_bytes, f.min_peak_bytes());
    }

    #[test]
    fn swept_budgets_are_sorted_and_include_zero() {
        let f = front(&networks::vgg(11));
        assert_eq!(f.swept_budgets[0], 0.0);
        for w in f.swept_budgets.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}

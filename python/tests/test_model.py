"""L2 performance-model tests: gradients, masking, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=10, deadline=None)


def small_model(seed=0, in_dim=5, out_dim=3):
    key = jax.random.PRNGKey(seed)
    return model.init_params(key, in_dim, [16, 32], out_dim)


def batch(seed, b=16, in_dim=5, out_dim=3, mask_p=0.3):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, in_dim))
    y = jax.random.normal(k2, (b, out_dim))
    mask = (jax.random.uniform(k3, (b, out_dim)) > mask_p).astype(jnp.float32)
    return x, y, mask


def test_apply_matches_oracle():
    p = small_model()
    x, _, _ = batch(1)
    np.testing.assert_allclose(
        model.apply(p, x), ref.mlp_apply(p, x), rtol=1e-4, atol=1e-5
    )


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_grads_match_oracle(seed):
    p = small_model(seed)
    x, y, mask = batch(seed + 1)

    def oracle(p):
        pred = ref.mlp_apply(p, x)
        se = (pred - y) ** 2 * mask
        return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)

    l1, g1 = jax.value_and_grad(model.masked_mse)(p, x, y, mask)
    l2, g2 = jax.value_and_grad(oracle)(p)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    for (a, b), (c, d) in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(b, d, rtol=1e-3, atol=1e-5)


def test_masked_labels_do_not_influence_training():
    """Poisoning masked-out labels with garbage must not change the step."""
    p = small_model()
    m, v = model.init_opt(p)
    x, y, mask = batch(7)
    y_poison = jnp.where(mask > 0, y, 1e6)
    out1 = model.train_step(p, m, v, jnp.float32(0), x, y, mask, 0.01, 0.0)
    out2 = model.train_step(p, m, v, jnp.float32(0), x, y_poison, mask, 0.01, 0.0)
    for (w1, b1), (w2, b2) in zip(out1[0], out2[0]):
        np.testing.assert_allclose(w1, w2)
        np.testing.assert_allclose(b1, b2)
    np.testing.assert_allclose(out1[4], out2[4])


def test_all_masked_batch_is_finite():
    p = small_model()
    m, v = model.init_opt(p)
    x, y, _ = batch(3)
    mask = jnp.zeros_like(y)
    p2, _, _, _, loss = model.train_step(p, m, v, jnp.float32(0), x, y, mask, 0.01, 0.0)
    assert jnp.isfinite(loss)
    for (w, b) in p2:
        assert jnp.all(jnp.isfinite(w)) and jnp.all(jnp.isfinite(b))


def test_training_descends():
    p = small_model()
    m, v = model.init_opt(p)
    t = jnp.float32(0)
    x, y, mask = batch(11)
    first = None
    for _ in range(50):
        p, m, v, t, loss = model.train_step(p, m, v, t, x, y, mask, 0.01, 0.0)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.5


def test_train_epoch_equals_steps():
    """One scanned epoch must equal the same batches applied step-by-step."""
    p = small_model()
    m, v = model.init_opt(p)
    t = jnp.float32(0)
    xs, ys, masks = [], [], []
    for i in range(3):
        x, y, mask = batch(20 + i)
        xs.append(x); ys.append(y); masks.append(mask)
    xs, ys, masks = jnp.stack(xs), jnp.stack(ys), jnp.stack(masks)

    pe, me, ve, te, _ = model.train_epoch(p, m, v, t, xs, ys, masks, 0.01, 1e-5)
    ps, ms, vs, ts = p, m, v, t
    for i in range(3):
        ps, ms, vs, ts, _ = model.train_step(
            ps, ms, vs, ts, xs[i], ys[i], masks[i], 0.01, 1e-5)
    assert float(te) == float(ts) == 3.0
    for (w1, b1), (w2, b2) in zip(pe, ps):
        np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-6)


def test_weight_decay_shrinks_params():
    p = small_model()
    m, v = model.init_opt(p)
    x, y, mask = batch(5)
    mask = jnp.zeros_like(mask)  # no data signal: only decay acts
    p2, *_ = model.train_step(p, m, v, jnp.float32(0), x, y, mask, 0.1, 0.5)
    for (w1, _), (w2, _) in zip(p, p2):
        assert float(jnp.linalg.norm(w2)) < float(jnp.linalg.norm(w1))


def test_flatten_round_trip():
    p = small_model()
    flat = model.flatten_params(p)
    assert len(flat) == 2 * len(p)
    p2 = model.unflatten_params(flat)
    for (w1, b1), (w2, b2) in zip(p, p2):
        assert w1 is w2 and b1 is b2


def test_model_kinds_shapes():
    from compile import constants as C
    for kind, (in_dim, hidden, out_dim) in model.MODEL_KINDS.items():
        sizes = model.layer_sizes(in_dim, hidden, out_dim)
        assert sizes[0] == in_dim and sizes[-1] == out_dim
        assert len(sizes) == 6  # paper Table 3: five dense layers
    assert model.MODEL_KINDS["nn2"][2] == C.N_PRIMITIVES
    assert model.MODEL_KINDS["dlt_nn2"][2] == C.N_DLT

"""AOT artifact sanity: manifest contract the rust side relies on."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as fh:
        return json.load(fh)


def test_manifest_constants():
    from compile import constants as C
    m = manifest()
    assert m["n_primitives"] == C.N_PRIMITIVES
    assert m["prim_features"] == C.PRIM_FEATURES
    assert m["dlt_features"] == C.DLT_FEATURES


def test_model_files_exist_and_parse():
    m = manifest()
    assert set(m["models"]) == {"nn1", "nn2", "dlt_nn1", "dlt_nn2"}
    for kind, spec in m["models"].items():
        assert len(spec["param_shapes"]) == 10  # 5 layers x (W, b)
        for fname in spec["files"].values():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), fname
            head = open(path).read(200)
            assert "HloModule" in head, fname


def test_param_shapes_consistent():
    from compile import model
    m = manifest()
    for kind, spec in m["models"].items():
        in_dim, hidden, out_dim = model.MODEL_KINDS[kind]
        sizes = model.layer_sizes(in_dim, hidden, out_dim)
        shapes = spec["param_shapes"]
        for i in range(len(sizes) - 1):
            assert shapes[2 * i] == [sizes[i], sizes[i + 1]]
            assert shapes[2 * i + 1] == [sizes[i + 1]]


def test_prim_grid_entries():
    import compile.kernels as K
    m = manifest()
    assert len(m["prim_grid"]) > 50
    for e in m["prim_grid"]:
        assert e["kernel"] in K.REGISTRY
        assert os.path.exists(os.path.join(ART, e["file"]))
        fn, layout, ok = K.REGISTRY[e["kernel"]]
        assert ok(e["f"], e["s"], e["im"])
        assert e["out_layout"] == layout
        assert e["flops"] > 0


def test_dlt_grid_entries():
    m = manifest()
    # 4 (c, im) pairs x 6 directed non-identity transforms
    assert len(m["dlt_grid"]) == 24
    for e in m["dlt_grid"]:
        assert e["src"] != e["dst"]
        assert os.path.exists(os.path.join(ART, e["file"]))

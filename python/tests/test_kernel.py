"""Kernel-vs-oracle correctness: the CORE signal of the L1 layer.

Every Pallas kernel in compile.kernels.REGISTRY is swept against the
pure-jnp oracle in ref.py with hypothesis-generated layer configurations
(shapes, kernel sizes, strides) from the paper's Table 1 ranges (scaled to
test-size images).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import compile.kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand_case(c, im, k, f, s, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(c, im, im)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, c, f, f)).astype(np.float32))
    return x, w


config_strategy = st.tuples(
    st.integers(1, 8),            # c
    st.integers(7, 24),           # im
    st.integers(1, 8),            # k
    st.sampled_from([1, 3, 5, 7]),  # f
    st.sampled_from([1, 2, 4]),   # s
    st.integers(0, 10_000),       # seed
)


@pytest.mark.parametrize("name", sorted(K.REGISTRY))
@settings(**SETTINGS)
@given(cfg=config_strategy)
def test_kernel_matches_oracle(name, cfg):
    c, im, k, f, s, seed = cfg
    fn, layout, ok = K.REGISTRY[name]
    if not ok(f, s, im):
        return
    x, w = rand_case(c, im, k, f, s, seed)
    gold = ref.to_layout(ref.conv2d(x, w, s), layout)
    got = fn(x, w, s)
    assert got.shape == gold.shape
    np.testing.assert_allclose(got, gold, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("name", sorted(K.REGISTRY))
def test_kernel_applicability_consistent(name):
    """Applicable kernels must run; constraint must reject f > im."""
    fn, layout, ok = K.REGISTRY[name]
    assert not ok(9, 1, 7)  # f > im never applicable


@settings(**SETTINGS)
@given(
    c=st.integers(1, 6), im=st.integers(4, 16),
    src=st.sampled_from(ref.LAYOUTS), dst=st.sampled_from(ref.LAYOUTS),
    seed=st.integers(0, 1000),
)
def test_dlt_kernel(c, im, src, dst, seed):
    rng = np.random.default_rng(seed)
    x_chw = jnp.asarray(rng.normal(size=(c, im, im)).astype(np.float32))
    x = ref.to_layout(x_chw, src)
    got = K.dlt_kernel(x, src, dst)
    gold = ref.dlt(x, src, dst)
    np.testing.assert_allclose(got, gold)
    # round trip restores the original
    back = K.dlt_kernel(got, dst, src)
    np.testing.assert_allclose(back, x)


@pytest.mark.parametrize("m,r", [(2, 3), (3, 3), (4, 3), (2, 5), (4, 5)])
def test_winograd_matrices_exact(m, r):
    """AT[(G g) * (BT d)] == correlate(d, g) for random vectors."""
    AT, G, BT = ref.winograd_matrices(m, r)
    rng = np.random.default_rng(m * 10 + r)
    for _ in range(5):
        g = rng.normal(size=r)
        d = rng.normal(size=m + r - 1)
        y = AT @ ((G @ g) * (BT @ d))
        gold = np.correlate(d, g, mode="valid")
        np.testing.assert_allclose(y, gold, rtol=1e-8, atol=1e-8)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 48), k=st.integers(1, 40), n=st.integers(1, 140),
    seed=st.integers(0, 1000),
)
def test_gemm_kernel(m, k, n, seed):
    from compile.kernels.gemm import gemm
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(gemm(a, b), a @ b, rtol=1e-4, atol=1e-4)


def test_out_size():
    assert ref.out_size(7, 3, 1) == 5
    assert ref.out_size(7, 3, 2) == 3
    assert ref.out_size(224, 7, 2) == 109
    with pytest.raises(AssertionError):
        ref.out_size(3, 5, 1)

"""Edge-case and structural tests beyond the hypothesis sweeps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import compile.kernels as K
from compile.kernels import ref


def case(c, im, k, f, s, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(c, im, im)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, c, f, f)).astype(np.float32))
    return x, w


@pytest.mark.parametrize("name", sorted(K.REGISTRY))
def test_minimum_image(name):
    """im == f: a single 1x1 output position."""
    fn, layout, ok = K.REGISTRY[name]
    for f in (1, 3, 5):
        if not ok(f, 1, f):
            continue
        x, w = case(2, f, 3, f, 1)
        gold = ref.to_layout(ref.conv2d(x, w, 1), layout)
        got = fn(x, w, 1)
        assert got.shape == gold.shape
        np.testing.assert_allclose(got, gold, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("name", sorted(K.REGISTRY))
def test_single_channel_single_kernel(name):
    fn, layout, ok = K.REGISTRY[name]
    f = 3 if ok(3, 1, 8) else 1
    if not ok(f, 1, 8):
        return
    x, w = case(1, 8, 1, f, 1)
    gold = ref.to_layout(ref.conv2d(x, w, 1), layout)
    np.testing.assert_allclose(fn(x, w, 1), gold, rtol=5e-3, atol=5e-3)


def test_winograd_partial_tiles():
    """Output size not divisible by the Winograd tile m."""
    for (m, name) in [(2, "winograd_2x2_3x3"), (4, "winograd_4x4_3x3")]:
        fn, layout, ok = K.REGISTRY[name]
        for im in (7, 9, 10, 13):
            o = im - 2
            if o % m == 0:
                continue  # want the ragged case
            x, w = case(3, im, 2, 3, 1, seed=im)
            gold = ref.conv2d(x, w, 1)
            np.testing.assert_allclose(fn(x, w, 1), gold, rtol=5e-3, atol=5e-3)


def test_stride_larger_than_kernel():
    """s=4 with f=3: strided windows skip input columns entirely."""
    for name in ("im2col_copy", "im2col_scan", "mec_col", "direct_sum2d"):
        fn, layout, ok = K.REGISTRY[name]
        assert ok(3, 4, 16)
        x, w = case(2, 16, 3, 3, 4)
        gold = ref.to_layout(ref.conv2d(x, w, 4), layout)
        np.testing.assert_allclose(fn(x, w, 4), gold, rtol=5e-3, atol=5e-3)


def test_large_channel_small_image():
    """Deep-network tail shapes: c >> im (e.g. 256 x 7 x 7)."""
    x, w = case(128, 7, 32, 3, 1)
    fn, layout, _ = K.REGISTRY["im2col_copy"]
    gold = ref.to_layout(ref.conv2d(x, w, 1), layout)
    np.testing.assert_allclose(fn(x, w, 1), gold, rtol=2e-2, atol=2e-2)


def test_dlt_all_nine_directed_pairs():
    rng = np.random.default_rng(1)
    x_chw = jnp.asarray(rng.normal(size=(4, 6, 6)).astype(np.float32))
    for src in ref.LAYOUTS:
        x = ref.to_layout(x_chw, src)
        for dst in ref.LAYOUTS:
            got = K.dlt_kernel(x, src, dst)
            np.testing.assert_allclose(got, ref.dlt(x, src, dst))
            if src == dst:
                assert got is x  # identity is free


def test_kernels_are_jittable():
    """Every kernel must lower under jax.jit (the AOT path requirement)."""
    for name, (fn, layout, ok) in K.REGISTRY.items():
        f = 3 if ok(3, 1, 8) else (1 if ok(1, 1, 8) else 5)
        if not ok(f, 1, 8):
            continue
        x, w = case(2, 8, 3, f, 1)
        jitted = jax.jit(lambda a, b, _fn=fn: _fn(a, b, 1))
        got = jitted(x, w)
        gold = ref.to_layout(ref.conv2d(x, w, 1), layout)
        np.testing.assert_allclose(got, gold, rtol=5e-3, atol=5e-3)


def test_hlo_text_export_round_trip():
    """The aot lowering path must produce parseable HLO text."""
    from compile import aot

    def fn(x, w):
        return (K.REGISTRY["kn2row"][0](x, w, 1),)

    spec = jax.ShapeDtypeStruct((2, 8, 8), jnp.float32)
    wspec = jax.ShapeDtypeStruct((3, 2, 3, 3), jnp.float32)
    lowered = jax.jit(fn).lower(spec, wspec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[3,6,6]" in text.replace(" ", "")


def test_mlp_dense_relu_boundary():
    """ReLU must clamp exactly at zero (fused epilogue correctness)."""
    from compile.kernels.mlp import dense
    x = jnp.array([[1.0, -1.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    y = dense(x, w, b, relu=True)
    np.testing.assert_allclose(y, [[1.0, 0.0]])
    y2 = dense(x, w, b, relu=False)
    np.testing.assert_allclose(y2, [[1.0, -1.0]])

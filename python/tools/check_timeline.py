#!/usr/bin/env python3
"""Timeline-export check: run the serving stack's fault-injection demo
with the ops plane live, export the flight recorder as Chrome
trace-event JSON, and validate the file against the subset of the trace
format the Chrome tracing UI / Perfetto actually require to render it.

Usage:
    python3 python/tools/check_timeline.py                # runs serve_zoo
    python3 python/tools/check_timeline.py --from-file F  # validate a file

The default producer is

    cargo run --release --example serve_zoo -- \
        --inject-faults --dashboard --timeline results/timeline.json

— fault injection guarantees health events land in the recorder (so the
timeline must carry instants, not just request spans), and the dashboard
flag brings up the sampler + SLO engine whose alert transitions ride the
same event ring.

Checks, stdlib only:
  * the file parses as JSON with a non-empty ``traceEvents`` array;
  * every event carries ``ph`` and ``pid``; phases are limited to the
    ones the exporter emits ("X" complete spans, "i" instants, "M"
    metadata);
  * every "X" span has a non-empty ``name``, numeric ``ts``/``dur``
    (``dur`` >= 0) and a ``tid``, and ``ts`` is monotone non-decreasing
    per ``(pid, tid)`` in array order (the tracing UI's sort contract);
  * every "i" instant is global-scoped (``s: "g"``) and has a ``ts``;
  * at least one stage-ladder span (a name like ``admit->dispatch``) and
    at least one health/alert instant are present — a timeline with no
    stage breakdown or no events means the wiring regressed;
  * ``process_name`` metadata covers every pid any event references.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

PRODUCER = [
    "cargo",
    "run",
    "--release",
    "--example",
    "serve_zoo",
    "--",
    "--inject-faults",
    "--dashboard",
    "--timeline",
    "results/timeline.json",
]
DEFAULT_PATH = "results/timeline.json"

KNOWN_PHASES = {"X", "i", "M"}


class CheckError(Exception):
    pass


def require_num(event: dict, key: str, where: str) -> float:
    v = event.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise CheckError(f"{where}: field {key!r} missing or non-numeric: {v!r}")
    return float(v)


def check(doc: object) -> dict[str, int]:
    if not isinstance(doc, dict):
        raise CheckError("trace root must be an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise CheckError("traceEvents must be a non-empty array")

    counts = {"X": 0, "i": 0, "M": 0}
    stage_spans = 0
    named_pids: set[float] = set()
    seen_pids: set[float] = set()
    last_ts: dict[tuple[float, float], float] = {}
    for n, e in enumerate(events):
        where = f"traceEvents[{n}]"
        if not isinstance(e, dict):
            raise CheckError(f"{where}: event is not an object")
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            raise CheckError(f"{where}: unexpected phase {ph!r}")
        counts[ph] += 1
        pid = require_num(e, "pid", where)
        seen_pids.add(pid)
        if ph == "X":
            name = e.get("name")
            if not isinstance(name, str) or not name:
                raise CheckError(f"{where}: span without a name")
            ts = require_num(e, "ts", where)
            dur = require_num(e, "dur", where)
            if dur < 0:
                raise CheckError(f"{where}: negative duration {dur}")
            tid = require_num(e, "tid", where)
            key = (pid, tid)
            if ts < last_ts.get(key, float("-inf")):
                raise CheckError(
                    f"{where}: ts {ts} regressed below {last_ts[key]} on pid/tid {key}"
                )
            last_ts[key] = ts
            if "->" in name and ": " not in name:
                stage_spans += 1
        elif ph == "i":
            require_num(e, "ts", where)
            if e.get("s") != "g":
                raise CheckError(f"{where}: instant must be global-scoped (s: 'g')")
        elif ph == "M" and e.get("name") == "process_name":
            named_pids.add(pid)

    if counts["X"] == 0:
        raise CheckError("no complete spans — no requests made it into the timeline")
    if stage_spans == 0:
        raise CheckError("no stage-ladder spans (e.g. 'admit->dispatch') in the timeline")
    if counts["i"] == 0:
        raise CheckError(
            "no instant events — fault injection must produce health/alert instants"
        )
    unnamed = sorted(seen_pids - named_pids)
    if unnamed:
        raise CheckError(f"pids without process_name metadata: {unnamed}")
    return counts


def produce() -> None:
    print(f"running: {' '.join(PRODUCER)}")
    proc = subprocess.run(PRODUCER, capture_output=True, text=True)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise CheckError(f"producer exited {proc.returncode}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--from-file",
        help="validate an existing trace file instead of running the example",
    )
    args = ap.parse_args()
    path = args.from_file or DEFAULT_PATH
    try:
        if not args.from_file:
            produce()
        with open(path) as fh:
            try:
                doc = json.load(fh)
            except json.JSONDecodeError as e:
                raise CheckError(f"{path} does not parse as JSON: {e}") from e
        counts = check(doc)
    except CheckError as e:
        print(f"FAIL: {e}")
        return 1
    except OSError as e:
        print(f"FAIL: cannot read {path}: {e}")
        return 1
    print(
        f"timeline check passed: {counts['X']} spans, {counts['i']} instants, "
        f"{counts['M']} metadata events in {path}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Bench-regression gate: diff a bench-JSON run against the committed
baseline and fail CI on regressions in the gated rows.

Usage:
    python3 python/tools/bench_gate.py \
        --baseline BENCH_baseline.json \
        --results results/bench_selection.json

The baseline file carries the gate policy alongside the numbers:

    {
      "suite": "selection",
      "gate": {"threshold_pct": 15.0, "rows": ["selection/..."]},
      "benches": [{"name": "selection/...", "median_ms": 12.3}, ...]
    }

Rows outside ``gate.rows`` are reported informationally but never fail
the build (cold rows are noisy; the gate tracks the warm serving rows
whose regressions are architectural, not environmental).

Self-seeding: a gated row whose baseline ``median_ms`` is null (the
state this file is committed in before any CI runner has produced real
numbers) is filled from the current results and the baseline is written
back, exiting 0 — the runner's first honest numbers become the baseline
to commit, rather than numbers invented on a different machine.

Instrumentation overhead: independent of the baseline, the gate compares
``select_one_warm_instrumented`` against ``select_one_warm_plan`` within
the same run and fails if tracing + metrics cost more than
``OVERHEAD_CAP_PCT`` (both rows come from the same process minutes
apart, so the comparison is machine-independent — it runs even on the
self-seeding pass).
"""

from __future__ import annotations

import argparse
import json
import sys


# Max tolerated overhead of the fully-instrumented warm select over the
# bare warm select, percent.
OVERHEAD_CAP_PCT = 5.0


def load(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def medians(doc: dict) -> dict[str, float | None]:
    return {b["name"]: b.get("median_ms") for b in doc.get("benches", [])}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--results", required=True, help="fresh bench-run JSON")
    ap.add_argument(
        "--threshold-pct",
        type=float,
        default=None,
        help="override the baseline's gate.threshold_pct",
    )
    args = ap.parse_args()

    baseline = load(args.baseline)
    results = load(args.results)
    gate = baseline.get("gate", {})
    threshold = args.threshold_pct if args.threshold_pct is not None else float(
        gate.get("threshold_pct", 15.0)
    )
    gated = list(gate.get("rows", []))

    base = medians(baseline)
    cur = medians(results)

    missing = [r for r in gated if r not in cur or cur[r] is None]
    if missing:
        print(f"FAIL: gated rows absent from {args.results}: {missing}")
        print("      (a renamed or deleted bench row silently ungates itself;")
        print("       update gate.rows in the baseline deliberately instead)")
        return 1

    # in-run instrumentation-overhead cap (machine-independent, so it
    # applies on the self-seeding pass too)
    overhead_ok = instrumentation_overhead(cur)

    # self-seed: fill null gated baselines from this run and write back
    to_seed = [r for r in gated if base.get(r) is None]
    if to_seed:
        by_name = {b["name"]: b for b in baseline.setdefault("benches", [])}
        for r in to_seed:
            row = by_name.get(r)
            if row is None:
                row = {"name": r}
                baseline["benches"].append(row)
            row["median_ms"] = cur[r]
            print(f"seeded {r}: median {cur[r]:.4f} ms")
        with open(args.baseline, "w") as fh:
            json.dump(baseline, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"seeded baseline written to {args.baseline} — commit it to arm the gate")
        return 0 if overhead_ok else 1

    failures = []
    print(f"bench gate: threshold +{threshold:.1f}% on {len(gated)} rows")
    for name in sorted(set(base) | set(cur)):
        b, c = base.get(name), cur.get(name)
        if b is None or c is None or b <= 0.0:
            continue
        delta = (c / b - 1.0) * 100.0
        is_gated = name in gated
        verdict = "ok"
        if is_gated and delta > threshold:
            verdict = "REGRESSION"
            failures.append((name, b, c, delta))
        mark = "*" if is_gated else " "
        print(f"  {mark} {name:<44} {b:>10.4f} -> {c:>10.4f} ms  ({delta:+7.2f}%)  {verdict}")

    if failures:
        print(f"FAIL: {len(failures)} gated row(s) regressed more than {threshold:.1f}%:")
        for name, b, c, delta in failures:
            print(f"  {name}: {b:.4f} -> {c:.4f} ms ({delta:+.2f}%)")
        return 1
    if not overhead_ok:
        return 1
    print(f"bench gate passed{speedup_note(cur)}")
    return 0


def instrumentation_overhead(cur: dict[str, float | None]) -> bool:
    """Compare the instrumented warm select — and the same row with the
    ops-plane series sampler busy in the background — against the bare
    warm select from the same run; print each overhead and return False
    if either exceeds ``OVERHEAD_CAP_PCT``. Missing rows pass (older
    result files)."""
    bare = cur.get("selection/select_one_warm_plan")
    if not bare or bare <= 0.0:
        return True
    ok = True
    comparisons = [
        ("warm_instrumented", "selection/select_one_warm_instrumented", "tracing"),
        ("warm_sampled", "selection/select_one_warm_sampled", "background sampling"),
    ]
    for label, row, what in comparisons:
        traced = cur.get(row)
        if traced is None:
            continue
        overhead = (traced / bare - 1.0) * 100.0
        print(
            f"instrumentation overhead: warm_plan {bare:.4f} ms -> "
            f"{label} {traced:.4f} ms ({overhead:+.2f}%, cap +{OVERHEAD_CAP_PCT:.1f}%)"
        )
        if overhead > OVERHEAD_CAP_PCT:
            print(
                f"FAIL: {label} warm select is {overhead:.2f}% slower than the bare "
                f"warm select (cap {OVERHEAD_CAP_PCT:.1f}%) — {what} must stay "
                "effectively free"
            )
            ok = False
    return ok


def speedup_note(cur: dict[str, float | None]) -> str:
    """Warm-plan vs cold-rebuild speedup for the summary line, when both
    rows are present in the results (acceptance target: >= 5x)."""
    warm = cur.get("selection/select_one_warm_plan")
    cold = cur.get("selection/select_one_cold")
    if warm and cold and warm > 0.0:
        return f" (warm-plan select speedup: {cold / warm:.1f}x over cold rebuild)"
    return ""


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Metrics-exposition check: run the serving stack's telemetry dump and
validate both exporters — the Prometheus text exposition and the JSON
snapshot — against the format rules and the required metric set.

Usage:
    python3 python/tools/check_metrics.py                # runs serve_zoo --metrics
    python3 python/tools/check_metrics.py --from-file F  # validate a captured dump

The producer (``cargo run --release --example serve_zoo -- --metrics``,
or ``primsel metrics``) delimits the two payloads with markers:

    === metrics: prometheus ===
    <prometheus text exposition>
    === metrics: json ===
    <one-line JSON snapshot>
    === metrics: end ===

Checks, stdlib only:
  * marker structure: all three markers present, in order, exactly once;
  * every exposition line is a comment (# HELP / # TYPE) or a sample
    matching ``name{labels} value``; names and label keys match the
    Prometheus grammar; label values use only valid escapes;
  * every sample's family has a # TYPE line, declared before samples;
  * the required metric families for the serving stack are all present;
  * summary families carry quantile/_sum/_count series;
  * the JSON payload parses and matches the registry snapshot schema
    ({"counters": [...], "gauges": [...], "histograms": [...]}, each
    entry carrying name/labels plus its value fields).
"""

from __future__ import annotations

import argparse
import json
import re
import subprocess
import sys

MARK_PROM = "=== metrics: prometheus ==="
MARK_JSON = "=== metrics: json ==="
MARK_END = "=== metrics: end ==="

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_KEY_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>-?(?:\d+(?:\.\d+)?(?:[eE][+-]?\d+)?|NaN|\+Inf|-Inf))$"
)
# Label values may use ONLY the three escapes the exposition format
# defines (\\, \", \n) — a lone backslash or any other escape is a
# producer bug (an unescaped value would round-trip wrong through
# Prometheus ingestion).
LABEL_RE = re.compile(
    r'^(?P<key>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\\\|\\"|\\n)*)"$'
)
# HELP text may use only \\ and \n (quotes are not special there).
HELP_TEXT_RE = re.compile(r"^(?:[^\\]|\\\\|\\n)*$")

# Every family the serving stack promises to export (underscore form;
# summary families are matched by their base name).
REQUIRED_FAMILIES = [
    "primsel_queue_depth",
    "primsel_queue_capacity",
    "primsel_service_workers",
    "primsel_tenant_admitted",
    "primsel_tenant_rejected",
    "primsel_tenant_served",
    "primsel_cache_cost_hits",
    "primsel_cache_cost_misses",
    "primsel_cache_cost_hit_ratio",
    "primsel_cache_plan_hits",
    "primsel_cache_plan_misses",
    "primsel_cache_plan_hit_ratio",
    "primsel_cache_front_hits",
    "primsel_cache_front_misses",
    "primsel_cache_front_hit_ratio",
    "primsel_health_state",
    "primsel_health_drift",
    "primsel_trace_stage_ms",
    "primsel_recorder_requests",
    "primsel_recorder_events",
    "primsel_recorder_slow",
    "primsel_recorder_requests_dropped",
    "primsel_recorder_events_dropped",
    "primsel_slo_state",
    "primsel_slo_burn_fast",
    "primsel_slo_burn_slow",
    "primsel_series_ticks",
]


class CheckError(Exception):
    pass


def split_sections(text: str) -> tuple[str, str]:
    lines = text.splitlines()
    try:
        i_prom = lines.index(MARK_PROM)
        i_json = lines.index(MARK_JSON)
        i_end = lines.index(MARK_END)
    except ValueError as e:
        raise CheckError(f"missing marker: {e}") from e
    if not i_prom < i_json < i_end:
        raise CheckError(
            f"markers out of order: prometheus@{i_prom}, json@{i_json}, end@{i_end}"
        )
    for mark in (MARK_PROM, MARK_JSON, MARK_END):
        if lines.count(mark) != 1:
            raise CheckError(f"marker {mark!r} appears {lines.count(mark)} times")
    prom = "\n".join(lines[i_prom + 1 : i_json])
    blob = "\n".join(lines[i_json + 1 : i_end])
    return prom, blob


def family_of(name: str) -> str:
    """Map a summary's _sum/_count series back to its base family."""
    for suffix in ("_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def check_prometheus(text: str) -> dict[str, str]:
    """Validate the exposition; return {family: type}."""
    types: dict[str, str] = {}
    samples: dict[str, int] = {}
    summary_parts: dict[str, set[str]] = {}
    for n, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or parts[3] not in ("counter", "gauge", "summary"):
                raise CheckError(f"line {n}: malformed TYPE comment: {line!r}")
            name = parts[2]
            if not NAME_RE.match(name):
                raise CheckError(f"line {n}: bad metric name {name!r}")
            if name in types:
                raise CheckError(f"line {n}: duplicate TYPE for {name}")
            types[name] = parts[3]
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not parts[3].strip():
                raise CheckError(f"line {n}: malformed HELP comment: {line!r}")
            if not NAME_RE.match(parts[2]):
                raise CheckError(f"line {n}: bad metric name in HELP: {parts[2]!r}")
            if not HELP_TEXT_RE.match(parts[3]):
                raise CheckError(f"line {n}: invalid escape in HELP text: {parts[3]!r}")
            continue
        if line.startswith("#"):
            continue  # other comments
        m = SAMPLE_RE.match(line)
        if not m:
            raise CheckError(f"line {n}: not a valid sample line: {line!r}")
        name = m.group("name")
        quantile = False
        if m.group("labels"):
            for part in m.group("labels").split(","):
                lm = LABEL_RE.match(part)
                if not lm:
                    raise CheckError(f"line {n}: bad label pair {part!r}")
                if not LABEL_KEY_RE.match(lm.group("key")):
                    raise CheckError(f"line {n}: bad label key {lm.group('key')!r}")
                quantile = quantile or lm.group("key") == "quantile"
        fam = family_of(name)
        if fam not in types:
            raise CheckError(f"line {n}: sample {name!r} precedes its # TYPE (family {fam})")
        samples[fam] = samples.get(fam, 0) + 1
        if types[fam] == "summary":
            parts = summary_parts.setdefault(fam, set())
            if name.endswith("_sum"):
                parts.add("sum")
            elif name.endswith("_count"):
                parts.add("count")
            elif quantile:
                parts.add("quantile")
    for fam, t in types.items():
        if samples.get(fam, 0) == 0:
            raise CheckError(f"family {fam} has a TYPE but no samples")
        if t == "summary" and summary_parts.get(fam, set()) != {"quantile", "sum", "count"}:
            raise CheckError(
                f"summary {fam} is missing series: have {sorted(summary_parts.get(fam, set()))}"
            )
    missing = [f for f in REQUIRED_FAMILIES if f not in types]
    if missing:
        raise CheckError(f"required families absent from the exposition: {missing}")
    return types


def check_json(blob: str) -> None:
    try:
        doc = json.loads(blob)
    except json.JSONDecodeError as e:
        raise CheckError(f"JSON snapshot does not parse: {e}") from e
    if not isinstance(doc, dict):
        raise CheckError("JSON snapshot root must be an object")
    for section in ("counters", "gauges", "histograms"):
        arr = doc.get(section)
        if not isinstance(arr, list):
            raise CheckError(f"JSON snapshot missing array {section!r}")
        for entry in arr:
            if not isinstance(entry, dict):
                raise CheckError(f"{section} entry is not an object: {entry!r}")
            if not isinstance(entry.get("name"), str):
                raise CheckError(f"{section} entry without a name: {entry!r}")
            if not isinstance(entry.get("labels"), dict):
                raise CheckError(f"{section} entry without labels: {entry!r}")
            want = (
                ("value",)
                if section in ("counters", "gauges")
                else ("count", "mean_ms", "p50_ms", "p95_ms", "max_ms", "sum_ms")
            )
            for field in want:
                if not isinstance(entry.get(field), (int, float)):
                    raise CheckError(f"{section} entry {entry['name']!r} missing {field!r}")
    if not doc["counters"] or not doc["gauges"] or not doc["histograms"]:
        raise CheckError("JSON snapshot has an empty section — the workload produced no metrics")


def produce() -> str:
    cmd = ["cargo", "run", "--release", "--example", "serve_zoo", "--", "--metrics"]
    print(f"running: {' '.join(cmd)}")
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise CheckError(f"producer exited {proc.returncode}")
    return proc.stdout


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--from-file",
        help="validate a captured dump instead of running the example",
    )
    args = ap.parse_args()
    try:
        if args.from_file:
            with open(args.from_file) as fh:
                text = fh.read()
        else:
            text = produce()
        prom, blob = split_sections(text)
        types = check_prometheus(prom)
        check_json(blob)
    except CheckError as e:
        print(f"FAIL: {e}")
        return 1
    print(
        f"metrics check passed: {len(types)} families "
        f"({sum(1 for t in types.values() if t == 'summary')} summaries), "
        f"all {len(REQUIRED_FAMILIES)} required families present, JSON snapshot valid"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Data-layout transformation (DLT) kernels: CHW ↔ HCW ↔ HWC transposes.

The paper's solver charges an edge cost whenever consecutive layers pick
primitives with mismatched output/input layouts; these are the kernels that
perform those nine directed transformations.  TPU mapping: a grid over the
leading dimension; each program re-permutes one slab in VMEM (pure VPU
shuffle work, bandwidth-bound — exactly why the simulator models DLT cost
from bytes moved).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _transpose_kernel(x_ref, o_ref, *, perm):
    o_ref[...] = jnp.transpose(x_ref[...], perm)


def dlt(x, src: str, dst: str):
    """Transform x from layout src to layout dst (both in ref.LAYOUTS)."""
    assert src in ref.LAYOUTS and dst in ref.LAYOUTS
    if src == dst:
        return x
    # permutation taking src axes order to dst axes order
    sperm = ref._PERM_FROM_CHW[src]
    dperm = ref._PERM_FROM_CHW[dst]
    perm = tuple(sperm.index(ax) for ax in dperm)
    out_shape = tuple(x.shape[i] for i in perm)
    return pl.pallas_call(
        functools.partial(_transpose_kernel, perm=perm),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        interpret=True,
    )(x)

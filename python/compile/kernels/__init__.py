"""L1: Pallas kernels for the paper's convolutional primitive families.

`REGISTRY` maps a *kernel id* (one representative implementation per
primitive-family variant) to `(fn, out_layout, constraint)` where
`fn(x_chw, w, s) -> out` in `out_layout`, and `constraint(f, s, im)` says
whether the kernel applies (paper §3.2.1: some R_i are undefined).

The rust catalog (rust/src/primitives/catalog.rs) maps each of the 31
modeled primitives onto one of these kernel ids.
"""

from . import conv1x1, direct, dlt, im2col, kn2, mec, ref, winograd
from .dlt import dlt as dlt_kernel
from .mlp import dense


def _any(f, s, im):
    return f <= im


def _stride1(f, s, im):
    return s == 1 and f <= im


def _wino(r):
    def ok(f, s, im):
        return s == 1 and f == r and im >= r
    return ok


def _one_by_one(f, s, im):
    return f == 1


# kernel id -> (fn, out_layout, applicability)
REGISTRY = {
    "direct_sum2d": (direct.direct_sum2d, "chw", _any),
    "im2col_copy": (im2col.im2col_copy, "chw", _any),
    "im2col_scan": (im2col.im2col_scan, "chw", _any),
    "im2row_copy": (im2col.im2row_copy, "hwc", _any),
    "im2row_scan": (im2col.im2row_scan, "hwc", _any),
    "kn2row": (kn2.kn2row, "chw", _stride1),
    "kn2col": (kn2.kn2col, "hwc", _stride1),
    "winograd_2x2_3x3": (winograd.winograd_2x2_3x3, "chw", _wino(3)),
    "winograd_3x3_3x3": (winograd.winograd_3x3_3x3, "chw", _wino(3)),
    "winograd_4x4_3x3": (winograd.winograd_4x4_3x3, "chw", _wino(3)),
    "winograd_2x2_5x5": (winograd.winograd_2x2_5x5, "chw", _wino(5)),
    "winograd_4x4_5x5": (winograd.winograd_4x4_5x5, "chw", _wino(5)),
    "conv1x1_ki": (conv1x1.conv1x1_ki, "chw", _one_by_one),
    "conv1x1_ik": (conv1x1.conv1x1_ik, "hwc", _one_by_one),
    "mec_col": (mec.mec_col, "hwc", _any),
}

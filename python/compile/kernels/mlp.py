"""Fused dense layer (x @ W + b, optional ReLU) as a Pallas kernel.

This is the compute body of the paper's performance models (NN1/NN2): five
stacked dense layers.  TPU mapping: output-tile grid; each program computes
one (bm, bn) tile with the full reduction in VMEM on the MXU, adds the bias
broadcast and applies ReLU on the VPU — a classic fused epilogue, so the
activation never round-trips to HBM between matmul and nonlinearity.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 512
BN = 512


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _dense_fwd_impl(x, w, b, relu: bool):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = min(BM, m)
    bn = min(BN, n)
    return pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dense(x, w, b, relu: bool):
    return _dense_fwd_impl(x, w, b, relu)


def _dense_vjp_fwd(x, w, b, relu: bool):
    y = _dense_fwd_impl(x, w, b, relu)
    return y, (x, w, y)


def _dense_vjp_bwd(relu: bool, res, gy):
    """Backward pass stays on the Pallas gemm kernel (MXU in both passes)."""
    from .gemm import gemm

    x, w, y = res
    if relu:
        gy = gy * (y > 0.0).astype(gy.dtype)
    gx = gemm(gy, w.T)
    gw = gemm(x.T, gy)
    gb = jnp.sum(gy, axis=0)
    return gx, gw, gb


_dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)


def dense(x, w, b, *, relu: bool):
    """Fused dense layer; differentiable (custom VJP over Pallas gemms).

    x: (B, in), w: (in, out), b: (out,) -> (B, out).
    """
    return _dense(x, w, b, relu)

"""direct-sum2d: the naive six-loop convolution as a Pallas kernel.

The paper's direct family walks (k, oh, ow) outputs and (c, fh, fw) inputs.
TPU mapping: grid over output channels k; each program holds the full input
image in VMEM and accumulates the f*f shifted strided slices on the VPU —
the inner (c, oh, ow) arithmetic is dense vector work, no MXU use (which is
exactly why direct is usually the slowest family on matmul hardware).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _direct_kernel(x_ref, w_ref, o_ref, *, f: int, s: int, o: int):
    x = x_ref[...]          # (c, im, im)
    wk = w_ref[...]         # (1, c, f, f)
    acc = jnp.zeros((o, o), jnp.float32)
    for fh in range(f):
        for fw in range(f):
            sl = x[:, fh : fh + (o - 1) * s + 1 : s, fw : fw + (o - 1) * s + 1 : s]
            acc = acc + jnp.sum(sl * wk[0, :, fh, fw][:, None, None], axis=0)
    o_ref[...] = acc[None]


def direct_sum2d(x, w, s: int):
    """x: (c, im, im) CHW, w: (k, c, f, f) -> (k, o, o) CHW."""
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = ref.out_size(im, f, s)
    import functools

    return pl.pallas_call(
        functools.partial(_direct_kernel, f=f, s=s, o=o),
        out_shape=jax.ShapeDtypeStruct((k, o, o), jnp.float32),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((c, im, im), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, c, f, f), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, o), lambda i: (i, 0, 0)),
        interpret=True,
    )(x, w)

"""kn2row / kn2col primitive family as Pallas kernels (stride-1 only).

The kn2 trick (Anderson et al. [2]): a f×f convolution is the sum of f*f
1×1 convolutions of the *whole* image, each shifted by its kernel offset.
Each 1×1 conv is a (k×c)·(c×im²) gemm — no patch matrix at all, the
memory-efficiency the paper highlights.  TPU mapping: grid over (fh, fw);
each program runs one MXU gemm and accumulates the offset-shifted window
into the output held in VMEM.  The paper notes kn2 degrades for s>1; the
catalog marks stride>1 as inapplicable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kn2_kernel(x_ref, w_ref, o_ref, *, f: int, im: int, o: int, col: bool):
    fh = pl.program_id(0)
    fw = pl.program_id(1)
    x = x_ref[...]                       # (c, im, im)
    wk = w_ref[...][:, :, 0, 0]          # (k, c)
    c = x.shape[0]
    g = jnp.dot(wk, x.reshape(c, im * im),
                preferred_element_type=jnp.float32).reshape(-1, im, im)
    win = jax.lax.dynamic_slice(g, (0, fh, fw), (g.shape[0], o, o))

    @pl.when(jnp.logical_and(fh == 0, fw == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if col:
        o_ref[...] += jnp.transpose(win, (1, 2, 0))
    else:
        o_ref[...] += win


def _kn2(x, w, s: int, col: bool):
    assert s == 1, "kn2 primitives are stride-1 only"
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = ref.out_size(im, f, 1)
    out_shape = (o, o, k) if col else (k, o, o)
    return pl.pallas_call(
        functools.partial(_kn2_kernel, f=f, im=im, o=o, col=col),
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        grid=(f, f),
        in_specs=[
            pl.BlockSpec((c, im, im), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((k, c, 1, 1), lambda i, j: (0, 0, i, j)),
        ],
        out_specs=pl.BlockSpec(out_shape, lambda i, j: (0, 0, 0)),
        interpret=True,
    )(x, w)


def kn2row(x, w, s: int):
    """kn2row: CHW output."""
    return _kn2(x, w, s, col=False)


def kn2col(x, w, s: int):
    """kn2col: HWC output."""
    return _kn2(x, w, s, col=True)

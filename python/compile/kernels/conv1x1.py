"""conv-1x1 primitive family: a 1×1 convolution is a single channel gemm.

The paper's eight conv-1x1-gemm-* variants differ in operand transposes
(`ab/atb/abt/atbt`) and output ordering (`ik/ki`); functionally they share
this kernel (one MXU gemm over the strided image), differing only in the
simulator's layout-dependent cost terms.
"""

import jax.numpy as jnp

from .gemm import gemm


def conv1x1_ki(x, w, s: int):
    """CHW output (`ki` ordering). x: (c, im, im), w: (k, c, 1, 1)."""
    k = w.shape[0]
    xs = x[:, ::s, ::s]
    c, o, _ = xs.shape
    out = gemm(w.reshape(k, c), xs.reshape(c, o * o))
    return out.reshape(k, o, o)


def conv1x1_ik(x, w, s: int):
    """HWC output (`ik` ordering)."""
    k = w.shape[0]
    xs = x[:, ::s, ::s]
    c, o, _ = xs.shape
    out = gemm(xs.reshape(c, o * o).T, w.reshape(k, c).T)  # (o*o, k)
    return out.reshape(o, o, k)

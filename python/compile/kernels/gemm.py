"""Shared blocked Pallas matmul used by the gemm-based primitive families.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles M×N across
programs; each program streams a (bm, K) × (K, bn) product through the MXU
with both operand tiles resident in VMEM.  K is kept as a single block —
for the paper's layer shapes the reduction dim (c·f·f ≤ 2048·121) times a
128-wide tile fits VMEM comfortably at the block sizes chosen here.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-friendly 128x128 output tiles.
BM = 128
BN = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.named_call, name="pallas_gemm")
def gemm(x, y, *, bm: int = BM, bn: int = BN):
    """Blocked matmul x @ y via a Pallas grid over output tiles.

    x: (M, K), y: (K, N) -> (M, N).  Handles non-divisible M/N via Pallas'
    automatic block padding.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, y)

"""im2col / im2row primitive families as Pallas kernels.

copy variants materialise the full patch matrix (c*f*f, o*o) in HBM via a
patch-extraction kernel (grid over (fh, fw) kernel offsets), then run one
big MXU gemm.  scan variants never materialise the patch matrix: a grid
over kernel offsets accumulates one strided-slice gemm per offset into the
output — trading the patch-matrix footprint for f*f smaller gemms (this is
the paper's distinction: copy is memory-hungry/fast, scan leaner).

Output layout: `ki`-ordered variants produce CHW; `ik`-ordered produce HWC
(the gemm result is written pixel-major).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .gemm import gemm


def _patch_kernel(x_ref, p_ref, *, f: int, s: int, o: int):
    fh = pl.program_id(0)
    fw = pl.program_id(1)
    x = x_ref[...]  # (c, im, im)
    span = (o - 1) * s + 1
    sl = jax.lax.dynamic_slice(x, (0, fh, fw), (x.shape[0], span, span))
    sl = sl[:, ::s, ::s]  # (c, o, o)
    p_ref[...] = sl.reshape(x.shape[0], 1, 1, o * o)


def _im2col_patches(x, f: int, s: int):
    """Materialise patches as (c, f, f, o*o); reshape = (c*f*f, o*o)."""
    c, im, _ = x.shape
    o = ref.out_size(im, f, s)
    p = pl.pallas_call(
        functools.partial(_patch_kernel, f=f, s=s, o=o),
        out_shape=jax.ShapeDtypeStruct((c, f, f, o * o), jnp.float32),
        grid=(f, f),
        in_specs=[pl.BlockSpec((c, im, im), lambda i, j: (0, 0, 0))],
        out_specs=pl.BlockSpec((c, 1, 1, o * o), lambda i, j: (0, i, j, 0)),
        interpret=True,
    )(x)
    return p.reshape(c * f * f, o * o)


def im2col_copy(x, w, s: int):
    """im2col copy variant, CHW output (`ki` ordering)."""
    k, c, f, _ = w.shape
    o = ref.out_size(x.shape[1], f, s)
    p = _im2col_patches(x, f, s)          # (c*f*f, o*o)
    out = gemm(w.reshape(k, c * f * f), p)
    return out.reshape(k, o, o)


def im2row_copy(x, w, s: int):
    """im2row copy variant, HWC output (`ik` ordering)."""
    k, c, f, _ = w.shape
    o = ref.out_size(x.shape[1], f, s)
    p = _im2col_patches(x, f, s)          # (c*f*f, o*o)
    out = gemm(p.T, w.reshape(k, c * f * f).T)  # (o*o, k)
    return out.reshape(o, o, k)


def _scan_step_kernel(x_ref, w_ref, o_ref, *, f: int, s: int, o: int):
    """One (fh, fw) offset: strided-slice the image, gemm, accumulate."""
    fh = pl.program_id(0)
    fw = pl.program_id(1)
    x = x_ref[...]           # (c, im, im)
    wk = w_ref[...]          # (k, c, 1, 1) slice at (fh, fw)
    c = x.shape[0]
    span = (o - 1) * s + 1
    sl = jax.lax.dynamic_slice(x, (0, fh, fw), (c, span, span))[:, ::s, ::s]
    g = jnp.dot(wk[:, :, 0, 0], sl.reshape(c, o * o),
                preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(fh == 0, fw == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += g.reshape(o_ref.shape)


def im2col_scan(x, w, s: int):
    """im2col scan variant: accumulate f*f offset gemms; CHW output."""
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = ref.out_size(im, f, s)
    return pl.pallas_call(
        functools.partial(_scan_step_kernel, f=f, s=s, o=o),
        out_shape=jax.ShapeDtypeStruct((k, o, o), jnp.float32),
        grid=(f, f),
        in_specs=[
            pl.BlockSpec((c, im, im), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((k, c, 1, 1), lambda i, j: (0, 0, i, j)),
        ],
        out_specs=pl.BlockSpec((k, o, o), lambda i, j: (0, 0, 0)),
        interpret=True,
    )(x, w)


def im2row_scan(x, w, s: int):
    """im2row scan variant; HWC output."""
    out = im2col_scan(x, w, s)
    return jnp.transpose(out, (1, 2, 0))

"""MEC — memory-efficient convolution (Cho & Brand) as a Pallas kernel.

MEC lowers the image over the *width* dimension only, into
L: (o, im, c*f) — a factor f smaller than the im2col patch matrix — and
then performs one small gemm per output row over a sliding height window
of L.  TPU mapping: the width-lowering is a (f,)-grid extraction kernel;
the per-row gemms are a (o,)-grid kernel, each staging a (o, f, c*f) VMEM
window and contracting on the MXU.  Low VMEM footprint is the family's
defining property, mirroring the paper's low-memory claim.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _lower_kernel(x_ref, l_ref, *, f: int, s: int, o: int):
    fw = pl.program_id(0)
    x = x_ref[...]  # (c, im, im)
    c, im, _ = x.shape
    span = (o - 1) * s + 1
    sl = jax.lax.dynamic_slice(x, (0, 0, fw), (c, im, span))[:, :, ::s]
    # L[ow, h, c, fw] slice for this fw
    l_ref[...] = jnp.transpose(sl, (2, 1, 0))[:, :, :, None]


def _row_kernel(l_ref, w_ref, o_ref, *, f: int, s: int):
    oh = pl.program_id(0)
    l = l_ref[...]          # (o, im, c*f)
    wflat = w_ref[...]      # (f, c*f, k)
    win = jax.lax.dynamic_slice(
        l, (0, oh * s, 0), (l.shape[0], f, l.shape[2])
    )  # (ow, fh, c*f)
    o_ref[...] = jnp.einsum("wfe,fek->wk", win, wflat)[None]


def mec_col(x, w, s: int):
    """mec-col: HWC output. x: (c, im, im), w: (k, c, f, f)."""
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = ref.out_size(im, f, s)
    L = pl.pallas_call(
        functools.partial(_lower_kernel, f=f, s=s, o=o),
        out_shape=jax.ShapeDtypeStruct((o, im, c, f), jnp.float32),
        grid=(f,),
        in_specs=[pl.BlockSpec((c, im, im), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((o, im, c, 1), lambda i: (0, 0, 0, i)),
        interpret=True,
    )(x).reshape(o, im, c * f)
    wflat = jnp.transpose(w, (2, 1, 3, 0)).reshape(f, c * f, k)
    out = pl.pallas_call(
        functools.partial(_row_kernel, f=f, s=s),
        out_shape=jax.ShapeDtypeStruct((o, o, k), jnp.float32),
        grid=(o,),
        in_specs=[
            pl.BlockSpec((o, im, c * f), lambda i: (0, 0, 0)),
            pl.BlockSpec((f, c * f, k), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, k), lambda i: (i, 0, 0)),
        interpret=True,
    )(L, wflat)
    return out  # (oh, ow, k) HWC

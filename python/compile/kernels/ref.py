"""Pure-jnp correctness oracles for every Pallas kernel in this package.

All convolutions are single-image (no batch dimension), VALID padding:
    out[k, oh, ow] = sum_{c, fh, fw} x[c, oh*s + fh, ow*s + fw] * w[k, c, fh, fw]
with output spatial size o = (im - f) // s + 1.

Layout conventions (the paper's three data layouts, section 3.2.2):
    CHW: (c, h, w)   HCW: (h, c, w)   HWC: (h, w, c)
Reference conv consumes/produces CHW; layout adapters are separate oracles.
"""

import jax
import jax.numpy as jnp


def out_size(im: int, f: int, s: int) -> int:
    """VALID-padding output spatial size."""
    assert f <= im, f"kernel {f} larger than image {im}"
    return (im - f) // s + 1


def conv2d(x, w, s: int):
    """Reference convolution. x: (c, im, im) CHW; w: (k, c, f, f); stride s.

    Returns (k, o, o) CHW. Uses lax.conv_general_dilated as the gold standard.
    """
    c, im, _ = x.shape
    k, c2, f, _ = w.shape
    assert c == c2, (x.shape, w.shape)
    lhs = x[None]  # NCHW with N=1
    out = jax.lax.conv_general_dilated(
        lhs, w, window_strides=(s, s), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def im2col_matrix(x, f: int, s: int):
    """Patch matrix P: (c*f*f, o*o) with P[(c,fh,fw), (oh,ow)] = x[c, oh*s+fh, ow*s+fw]."""
    c, im, _ = x.shape
    o = out_size(im, f, s)
    patches = []
    for fh in range(f):
        for fw in range(f):
            sl = x[:, fh : fh + (o - 1) * s + 1 : s, fw : fw + (o - 1) * s + 1 : s]
            patches.append(sl.reshape(c, o * o))
    p = jnp.stack(patches, axis=1)  # (c, f*f, o*o)
    return p.reshape(c * f * f, o * o)


def im2row_matrix(x, f: int, s: int):
    """Row patch matrix: (o*o, c*f*f) — transpose of im2col_matrix."""
    return im2col_matrix(x, f, s).T


def conv2d_im2col(x, w, s: int):
    """im2col reference: gemm over the patch matrix; CHW output."""
    k, c, f, _ = w.shape
    o = out_size(x.shape[1], f, s)
    p = im2col_matrix(x, f, s)              # (c*f*f, o*o)
    wm = w.reshape(k, c * f * f)            # (k, c*f*f)
    return (wm @ p).reshape(k, o, o)


def conv2d_kn2row(x, w, s: int):
    """kn2row reference: f*f shifted 1x1 gemms accumulated (stride 1 only)."""
    assert s == 1
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = out_size(im, f, s)
    acc = jnp.zeros((k, o, o), x.dtype)
    xm = x.reshape(c, im * im)
    for fh in range(f):
        for fw in range(f):
            g = (w[:, :, fh, fw] @ xm).reshape(k, im, im)
            acc = acc + g[:, fh : fh + o, fw : fw + o]
    return acc


def winograd_matrices(m: int, r: int):
    """Toom-Cook construction of Winograd F(m, r) transform matrices.

    Returns float64 numpy (AT: m x a, G: a x r, BT: a x a), a = m + r - 1,
    such that for 1-D correlation  y = AT @ [ (G @ g) * (BT @ d) ].
    Interpolation points follow the wincnn convention: 0, 1, -1, 2, -2, ...

    Derivation (transpose trick): the minimal linear convolution of length-m
    and length-r sequences is  s = Va^-1 [(Er g) * (Em d)]  via Toom-Cook on
    a-1 finite points plus the point at infinity.  Correlation with data
    length a is the transpose of the convolution-by-g map, which yields
    AT = Em^T, G = Er, BT = Va^-T.
    """
    import numpy as np

    a = m + r - 1
    pts = [0.0]
    mag = 1
    while len(pts) < a - 1:
        for cand in (float(mag), float(-mag), 1.0 / (mag + 1), -1.0 / (mag + 1)):
            if len(pts) < a - 1 and cand not in pts:
                pts.append(cand)
        mag += 1
    pts = np.array(pts[: a - 1], dtype=np.float64)

    def eval_matrix(cols):
        """Evaluation matrix of a degree-(cols-1) polynomial at pts + infinity."""
        mat = np.zeros((a, cols))
        for i in range(a - 1):
            mat[i] = pts[i] ** np.arange(cols)
        mat[a - 1, cols - 1] = 1.0  # the point at infinity picks the top coeff
        return mat

    Va = eval_matrix(a)
    Em = eval_matrix(m)
    Er = eval_matrix(r)
    AT = Em.T.copy()                   # m x a
    G = Er                             # a x r
    BT = np.linalg.inv(Va).T.copy()    # a x a
    return AT, G, BT


def conv2d_winograd(x, w, m: int):
    """2-D Winograd F(m x m, r x r) reference (stride 1)."""
    c, im, _ = x.shape
    k, _, r, _ = w.shape
    o = out_size(im, r, 1)
    ATn, Gn, BTn = winograd_matrices(m, r)
    a = m + r - 1
    AT = jnp.asarray(ATn, x.dtype)
    G = jnp.asarray(Gn, x.dtype)
    BT = jnp.asarray(BTn, x.dtype)

    tiles = -(-o // m)  # ceil
    pad = (tiles - 1) * m + a - im
    xp = jnp.pad(x, ((0, 0), (0, max(pad, 0)), (0, max(pad, 0))))

    U = jnp.einsum("ar,kcrq,bq->abkc", G, w, G)          # filter transform
    idx = [int(i) * m for i in range(tiles)]
    d = jnp.stack([
        jnp.stack([
            jax.lax.dynamic_slice(xp, (0, i, j), (c, a, a))
            for j in idx], axis=0)
        for i in idx], axis=0)                            # (t, t, c, a, a)
    V = jnp.einsum("ar,ijcrq,bq->abijc", BT, d, BT)       # input transform
    M = jnp.einsum("abkc,abijc->abijk", U, V)             # element-wise gemm
    Y = jnp.einsum("ma,abijk,nb->ijkmn", AT, M, AT)       # output transform
    out = jnp.transpose(Y, (2, 0, 3, 1, 4)).reshape(k, tiles * m, tiles * m)
    return out[:, :o, :o]


def conv2d_1x1(x, w, s: int):
    """1x1 convolution reference: channel gemm on (optionally) strided input."""
    k = w.shape[0]
    xs = x[:, ::s, ::s]
    c, o, _ = xs.shape
    return (w.reshape(k, c) @ xs.reshape(c, o * o)).reshape(k, o, o)


def conv2d_mec_col(x, w, s: int):
    """MEC (memory-efficient convolution) reference, column-lowering variant.

    Lowers over the width dimension only into L: (o, im, c*f), then performs
    one small gemm per output row. Numerically identical to conv2d.
    """
    c, im, _ = x.shape
    k, _, f, _ = w.shape
    o = out_size(im, f, s)
    cols = []
    for fw in range(f):
        cols.append(x[:, :, fw : fw + (o - 1) * s + 1 : s])  # (c, im, o)
    L = jnp.stack(cols, axis=1)                               # (c, f, im, o)
    L = jnp.transpose(L, (3, 2, 0, 1)).reshape(o, im, c * f)  # (ow, h, c*fw)
    wflat = jnp.transpose(w, (2, 1, 3, 0)).reshape(f, c * f, k)  # (fh, (c,fw), k)
    rows = []
    for oh in range(o):
        sl = L[:, oh * s : oh * s + f, :]          # (ow, fh, c*fw)
        rows.append(jnp.einsum("wfe,fek->wk", sl, wflat))
    out = jnp.stack(rows, axis=0)                  # (oh, ow, k)
    return jnp.transpose(out, (2, 0, 1))


# ---------------------------------------------------------------------------
# layout adapters (the three paper layouts)

LAYOUTS = ("chw", "hcw", "hwc")

_PERM_FROM_CHW = {"chw": (0, 1, 2), "hcw": (1, 0, 2), "hwc": (1, 2, 0)}


def to_layout(x_chw, layout: str):
    return jnp.transpose(x_chw, _PERM_FROM_CHW[layout])


def from_layout(x, layout: str):
    perm = _PERM_FROM_CHW[layout]
    inv = [perm.index(i) for i in range(3)]
    return jnp.transpose(x, tuple(inv))


def dlt(x, src: str, dst: str):
    """Data-layout transformation oracle."""
    return to_layout(from_layout(x, src), dst)


# ---------------------------------------------------------------------------
# performance-model MLP oracle

def mlp_apply(params, x):
    """params: list of (W, b); ReLU between hidden layers, linear head."""
    h = x
    for i, (wt, b) in enumerate(params):
        h = h @ wt + b
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h

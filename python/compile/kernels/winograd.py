"""Winograd F(m×m, r×r) convolution as a Pallas kernel (stride-1, r ∈ {3,5}).

Structure (Lavin & Gray): filter transform U = G w G^T (done once per call,
plain jnp — it is weight preparation, not the hot loop), then per input
tile: input transform V = B^T d B, element-wise channel gemms M = U·V, and
output transform Y = A^T M A.

TPU mapping: the grid walks the (tiles × tiles) output tiling; each program
stages one (c, a, a) input tile in VMEM, performs the a² batched (k×c)·(c)
contractions on the MXU and the two small transform matmuls on the VPU.
The `-vec-N` variants of the paper map to the lane-width of the tile batch;
they share this kernel and differ only in the simulator cost model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _wino_kernel(xp_ref, u_ref, bt_ref, at_ref, o_ref, *, c, a, m, k):
    ti = pl.program_id(0)
    tj = pl.program_id(1)
    xp = xp_ref[...]
    u = u_ref[...]        # (a, a, k, c)
    bt = bt_ref[...]      # (a, a)
    at = at_ref[...]      # (m, a)
    d = jax.lax.dynamic_slice(xp, (0, ti * m, tj * m), (c, a, a))
    v = jnp.einsum("ar,crq,bq->abc", bt, d, bt)          # input transform
    mm = jnp.einsum("abkc,abc->abk", u, v)               # MXU contractions
    y = jnp.einsum("ma,abk,nb->kmn", at, mm, at)         # output transform
    o_ref[...] = y[None]


def _winograd(x, w, m: int):
    c, im, _ = x.shape
    k, _, r, _ = w.shape
    o = ref.out_size(im, r, 1)
    a = m + r - 1
    ATn, Gn, BTn = ref.winograd_matrices(m, r)
    at = jnp.asarray(ATn, jnp.float32)
    g = jnp.asarray(Gn, jnp.float32)
    bt = jnp.asarray(BTn, jnp.float32)

    tiles = -(-o // m)
    pad = (tiles - 1) * m + a - im
    xp = jnp.pad(x, ((0, 0), (0, max(pad, 0)), (0, max(pad, 0))))
    u = jnp.einsum("ar,kcrq,bq->abkc", g, w, g)  # filter transform (prep)

    imp = xp.shape[1]
    # output tile rows are indexed by the flat tile id i * tiles + j
    out = pl.pallas_call(
        functools.partial(_wino_kernel, c=c, a=a, m=m, k=k),
        out_shape=jax.ShapeDtypeStruct((tiles * tiles, k, m, m), jnp.float32),
        grid=(tiles, tiles),
        in_specs=[
            pl.BlockSpec((c, imp, imp), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((a, a, k, c), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((a, a), lambda i, j: (0, 0)),
            pl.BlockSpec((m, a), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, k, m, m), lambda i, j, _t=tiles: (i * _t + j, 0, 0, 0)
        ),
        interpret=True,
    )(xp, u, bt, at)
    y = out.reshape(tiles, tiles, k, m, m)
    y = jnp.transpose(y, (2, 0, 3, 1, 4)).reshape(k, tiles * m, tiles * m)
    return y[:, :o, :o]


def winograd_2x2_3x3(x, w, s: int):
    assert s == 1 and w.shape[2] == 3
    return _winograd(x, w, 2)


def winograd_3x3_3x3(x, w, s: int):
    assert s == 1 and w.shape[2] == 3
    return _winograd(x, w, 3)


def winograd_4x4_3x3(x, w, s: int):
    assert s == 1 and w.shape[2] == 3
    return _winograd(x, w, 4)


def winograd_2x2_5x5(x, w, s: int):
    assert s == 1 and w.shape[2] == 5
    return _winograd(x, w, 2)


def winograd_4x4_5x5(x, w, s: int):
    assert s == 1 and w.shape[2] == 5
    return _winograd(x, w, 4)

"""Shared constants between the python compile path and the rust coordinator.

The rust side has its own authoritative catalog (rust/src/primitives/catalog.rs);
aot.py writes artifacts/manifest.json so rust can cross-check these at load
time.  Keep the two in sync — the manifest check fails loudly otherwise.
"""

# Number of modeled convolutional primitives (rows of the NN2 output).
# Must match rust/src/primitives/catalog.rs::CATALOG.len().
N_PRIMITIVES = 31

# Number of data layouts (CHW, HCW, HWC) -> 9 directed DLT costs.
N_LAYOUTS = 3
N_DLT = N_LAYOUTS * N_LAYOUTS

# Input feature dimensions of the performance models.
PRIM_FEATURES = 5  # (k, c, im, s, f), log-standardised
DLT_FEATURES = 2   # (c, im), log-standardised

# MLP architectures (paper Table 3).
NN1_HIDDEN = [16, 64, 64, 16]
NN2_HIDDEN = [128, 512, 512, 128]

# Batch shapes baked into the AOT artifacts.
TRAIN_BATCH = 1024   # paper Table 3 batch size
PREDICT_BATCH_LARGE = 1024  # test-set evaluation
PREDICT_BATCH_SMALL = 64    # one CNN's layer configs at once

"""AOT export: lower every L2/L1 computation to HLO text for the rust runtime.

Interchange format is HLO *text*, NOT serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts written to --out (default ../artifacts):
  {kind}_init.hlo.txt            seed:i32 -> flat params
  {kind}_train_step.hlo.txt      params,m,v,t,x,y,mask,lr,wd -> params,m,v,t,loss
  {kind}_train_epoch.hlo.txt     scan over EPOCH_BATCHES batches in one module
  {kind}_predict_b{B}.hlo.txt    params, x:(B,in) -> (B,out)
  prim_{kernel}_c{c}_im{im}_k{k}_f{f}_s{s}.hlo.txt    x,w -> out
  dltk_{src}_{dst}_c{c}_im{im}.hlo.txt                x -> y
  manifest.json                  shapes/order contract for the rust side

Run via `make artifacts`; python never executes at request time.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import constants as C
from . import model
from . import kernels
from .kernels import ref

# Fixed number of batches baked into the train_epoch artifact. The zoo
# enumeration yields ~6.2k configs -> 80% train split -> 5 batches of 1024
# with padding; matching this exactly lets the rust trainer run one PJRT
# call per epoch (scan) instead of one per step (see EXPERIMENTS.md §Perf).
EPOCH_BATCHES = 5

# The measured-profile grid: real Pallas kernel executions the rust
# profiler times on the host CPU (grounding the simulator's cost shapes).
PRIM_GRID = [
    # (c, im, k, f, s)
    (16, 32, 32, 3, 1),
    (32, 16, 64, 3, 1),
    (64, 14, 128, 3, 1),
    (16, 32, 32, 5, 1),
    (32, 28, 64, 1, 1),
    (64, 14, 128, 1, 2),
    (16, 32, 32, 3, 2),
    (8, 64, 16, 7, 2),
    (32, 28, 64, 5, 1),
    (3, 64, 16, 3, 1),
]

DLT_GRID = [(16, 32), (64, 14), (32, 28), (8, 64)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_model_kind(kind, out_dir, manifest):
    in_dim, hidden, out_dim = model.MODEL_KINDS[kind]
    sizes = model.layer_sizes(in_dim, hidden, out_dim)
    param_shapes = []
    for i in range(len(sizes) - 1):
        param_shapes.append((sizes[i], sizes[i + 1]))  # W
        param_shapes.append((sizes[i + 1],))           # b
    flat_specs = [f32(s) for s in param_shapes]
    n_layers = len(sizes) - 1

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        params = model.init_params(key, in_dim, hidden, out_dim)
        return tuple(model.flatten_params(params))

    def unflatten(flat):
        return model.unflatten_params(list(flat))

    def step_fn(*args):
        p = unflatten(args[:2 * n_layers])
        m = unflatten(args[2 * n_layers:4 * n_layers])
        v = unflatten(args[4 * n_layers:6 * n_layers])
        t, x, y, mask, lr, wd = args[6 * n_layers:]
        p, m, v, t, loss = model.train_step(p, m, v, t, x, y, mask, lr, wd)
        return tuple(model.flatten_params(p) + model.flatten_params(m)
                     + model.flatten_params(v) + [t, loss])

    def epoch_fn(*args):
        p = unflatten(args[:2 * n_layers])
        m = unflatten(args[2 * n_layers:4 * n_layers])
        v = unflatten(args[4 * n_layers:6 * n_layers])
        t, xs, ys, masks, lr, wd = args[6 * n_layers:]
        p, m, v, t, loss = model.train_epoch(p, m, v, t, xs, ys, masks, lr, wd)
        return tuple(model.flatten_params(p) + model.flatten_params(m)
                     + model.flatten_params(v) + [t, loss])

    def predict_fn(*args):
        p = unflatten(args[:2 * n_layers])
        x = args[2 * n_layers]
        return (model.apply(p, x),)

    B = C.TRAIN_BATCH
    scalar = f32(())
    state_specs = flat_specs * 3  # params, m, v
    files = {}

    path = os.path.join(out_dir, f"{kind}_init.hlo.txt")
    lower_to_file(init_fn, [jax.ShapeDtypeStruct((), jnp.int32)], path)
    files["init"] = os.path.basename(path)

    step_args = state_specs + [scalar, f32((B, in_dim)), f32((B, out_dim)),
                               f32((B, out_dim)), scalar, scalar]
    path = os.path.join(out_dir, f"{kind}_train_step.hlo.txt")
    lower_to_file(step_fn, step_args, path)
    files["train_step"] = os.path.basename(path)

    nb = EPOCH_BATCHES
    epoch_args = state_specs + [scalar, f32((nb, B, in_dim)),
                                f32((nb, B, out_dim)), f32((nb, B, out_dim)),
                                scalar, scalar]
    path = os.path.join(out_dir, f"{kind}_train_epoch.hlo.txt")
    lower_to_file(epoch_fn, epoch_args, path)
    files["train_epoch"] = os.path.basename(path)

    for b in (C.PREDICT_BATCH_SMALL, C.PREDICT_BATCH_LARGE):
        path = os.path.join(out_dir, f"{kind}_predict_b{b}.hlo.txt")
        lower_to_file(predict_fn, flat_specs + [f32((b, in_dim))], path)
        files[f"predict_b{b}"] = os.path.basename(path)

    manifest["models"][kind] = {
        "in_dim": in_dim,
        "out_dim": out_dim,
        "hidden": list(hidden),
        "param_shapes": [list(s) for s in param_shapes],
        "train_batch": B,
        "epoch_batches": nb,
        "files": files,
    }


def export_prim_grid(out_dir, manifest):
    entries = []
    for (c, im, k, f, s) in PRIM_GRID:
        for name, (fn, layout, ok) in kernels.REGISTRY.items():
            if not ok(f, s, im):
                continue
            o = ref.out_size(im, f, s)
            fname = f"prim_{name}_c{c}_im{im}_k{k}_f{f}_s{s}.hlo.txt"

            def wrapped(x, w, _fn=fn, _s=s):
                return (_fn(x, w, _s),)

            lower_to_file(
                wrapped, [f32((c, im, im)), f32((k, c, f, f))],
                os.path.join(out_dir, fname),
            )
            flops = 2.0 * k * c * f * f * o * o
            entries.append({
                "kernel": name, "c": c, "im": im, "k": k, "f": f, "s": s,
                "out_layout": layout, "flops": flops, "file": fname,
            })
    manifest["prim_grid"] = entries


def export_dlt_grid(out_dir, manifest):
    entries = []
    for (c, im) in DLT_GRID:
        for src in ref.LAYOUTS:
            for dst in ref.LAYOUTS:
                if src == dst:
                    continue
                fname = f"dltk_{src}_{dst}_c{c}_im{im}.hlo.txt"
                shape = {
                    "chw": (c, im, im), "hcw": (im, c, im), "hwc": (im, im, c)
                }[src]

                def wrapped(x, _src=src, _dst=dst):
                    return (kernels.dlt_kernel(x, _src, _dst),)

                lower_to_file(wrapped, [f32(shape)],
                              os.path.join(out_dir, fname))
                entries.append({
                    "src": src, "dst": dst, "c": c, "im": im,
                    "bytes": 4 * c * im * im, "file": fname,
                })
    manifest["dlt_grid"] = entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-prims", action="store_true",
                    help="models only (faster dev cycle)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "n_primitives": C.N_PRIMITIVES,
        "n_layouts": C.N_LAYOUTS,
        "prim_features": C.PRIM_FEATURES,
        "dlt_features": C.DLT_FEATURES,
        "predict_batches": [C.PREDICT_BATCH_SMALL, C.PREDICT_BATCH_LARGE],
        "models": {},
    }
    for kind in model.MODEL_KINDS:
        print(f"lowering {kind} ...", flush=True)
        export_model_kind(kind, args.out, manifest)
    if not args.skip_prims:
        print("lowering primitive grid ...", flush=True)
        export_prim_grid(args.out, manifest)
        print("lowering dlt grid ...", flush=True)
        export_dlt_grid(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    n = len([f for f in os.listdir(args.out) if f.endswith(".hlo.txt")])
    print(f"wrote {n} HLO artifacts to {args.out}")


if __name__ == "__main__":
    main()

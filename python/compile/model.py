"""L2: the paper's performance models (NN1 / NN2 / DLT variants) in JAX.

Architecture (paper Table 3):
    NN1: in -> 16 -> 64 -> 64 -> 16 -> 1        (one model per primitive)
    NN2: in -> 128 -> 512 -> 512 -> 128 -> n    (one model for all primitives)
ReLU between layers, linear head.  The dense layers are the Pallas `dense`
kernel from kernels/mlp.py, so the whole model lowers into one HLO module.

Training follows the paper §3.3: masked MSE on log-standardised targets
(undefined R_i are masked out of the loss *and* the gradients — achieved
here simply by multiplying the squared error by the 0/1 mask, which zeroes
the corresponding cotangents), Adam, runtime lr / weight-decay scalars so
the same AOT artifact serves both initial training and fine-tuning (the
paper lowers lr by 10x for fine-tuning).

Everything here is lowered once by aot.py; python never runs at request
time.  Parameter pytrees are flattened in a fixed order (W0,b0,...,W4,b4)
recorded in artifacts/manifest.json for the rust ParamStore.
"""

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels.mlp import dense

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def layer_sizes(in_dim: int, hidden: list, out_dim: int):
    return [in_dim] + list(hidden) + [out_dim]


def init_params(key, in_dim: int, hidden: list, out_dim: int):
    """He-initialised parameter list [(W, b), ...]."""
    sizes = layer_sizes(in_dim, hidden, out_dim)
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / sizes[i])
        b = jnp.zeros((sizes[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def apply(params, x):
    """Forward pass on the Pallas dense kernel; x: (B, in) -> (B, out)."""
    h = x
    for i, (w, b) in enumerate(params):
        h = dense(h, w, b, relu=(i < len(params) - 1))
    return h


def masked_mse(params, x, y, mask):
    """Paper §3.3 loss: squared error only over defined labels."""
    pred = apply(params, x)
    se = (pred - y) ** 2 * mask
    return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)


def init_opt(params):
    m = [(jnp.zeros_like(w), jnp.zeros_like(b)) for (w, b) in params]
    v = [(jnp.zeros_like(w), jnp.zeros_like(b)) for (w, b) in params]
    return m, v


def train_step(params, m, v, t, x, y, mask, lr, wd):
    """One masked-MSE Adam step with decoupled weight decay.

    t is the 1-based step counter (float32 scalar); lr/wd are runtime
    scalars.  Returns (params', m', v', t+1, loss).
    """
    loss, grads = jax.value_and_grad(masked_mse)(params, x, y, mask)
    t = t + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t

    new_params, new_m, new_v = [], [], []
    for (p, g, mi, vi) in zip(params, grads, m, v):
        layer_p, layer_m, layer_v = [], [], []
        for (pj, gj, mj, vj) in zip(p, g, mi, vi):
            mj = ADAM_B1 * mj + (1.0 - ADAM_B1) * gj
            vj = ADAM_B2 * vj + (1.0 - ADAM_B2) * gj * gj
            upd = (mj / bc1) / (jnp.sqrt(vj / bc2) + ADAM_EPS)
            pj = pj - lr * (upd + wd * pj)
            layer_p.append(pj)
            layer_m.append(mj)
            layer_v.append(vj)
        new_params.append(tuple(layer_p))
        new_m.append(tuple(layer_m))
        new_v.append(tuple(layer_v))
    return new_params, new_m, new_v, t, loss


def train_epoch(params, m, v, t, xs, ys, masks, lr, wd):
    """lax.scan over a fixed number of batches inside one HLO module.

    xs: (nb, B, in), ys/masks: (nb, B, out).  One PJRT call per epoch
    instead of per step — the L2 perf optimisation from DESIGN.md §9.
    """
    def step(carry, batch):
        params, m, v, t = carry
        x, y, mask = batch
        params, m, v, t, loss = train_step(params, m, v, t, x, y, mask, lr, wd)
        return (params, m, v, t), loss

    (params, m, v, t), losses = jax.lax.scan(
        step, (params, m, v, t), (xs, ys, masks)
    )
    return params, m, v, t, jnp.mean(losses)


# ---------------------------------------------------------------------------
# model-kind registry used by aot.py

MODEL_KINDS = {
    # name:     (in_dim, hidden, out_dim)
    "nn2": (C.PRIM_FEATURES, C.NN2_HIDDEN, C.N_PRIMITIVES),
    "nn1": (C.PRIM_FEATURES, C.NN1_HIDDEN, 1),
    "dlt_nn2": (C.DLT_FEATURES, C.NN2_HIDDEN, C.N_DLT),
    "dlt_nn1": (C.DLT_FEATURES, C.NN1_HIDDEN, 1),
}


def flatten_params(params):
    """Deterministic flat order: W0, b0, W1, b1, ..."""
    flat = []
    for (w, b) in params:
        flat.append(w)
        flat.append(b)
    return flat


def unflatten_params(flat):
    assert len(flat) % 2 == 0
    return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]
